"""Declarative fault injection over recorded step traces.

Each fault is a frozen, parameter-only dataclass; :meth:`Fault.plan`
resolves it against one (trace, instantiated PSG, scale, seed) into a
:class:`FaultPlan` — the concrete replay-engine inputs (vectorized base
times, ``{(proc, vid): extra_seconds}`` injection table, scaling law) plus
the machine-checkable ground truth (target vertices, culprit processes).
Resolution is deterministic: the same (scenario, scale, seed) always
yields bit-identical plans, which is what lets the bank assert accuracy
floors and the property tests assert run-to-run reproducibility.

Faults model the paper's evaluation faults at jax scale:

  * :class:`MoEImbalance`   — hot experts: a proc subset runs long in the
    MoE dispatch compute; the all-to-all exposes it as wait.
  * :class:`PipelineBubble` — one straggler stage; the ring neighbor
    exchange stalls the pipeline behind it.
  * :class:`DataStall`      — the input pipeline stalls a random proc
    subset in the first compute vertex of the step.
  * :class:`BatchSkew`      — serving: uneven per-proc batch occupancy
    scales the dominant decode compute multiplicatively.
  * :class:`SerialFraction` — Amdahl: part of the heaviest vertex does
    not parallelize; surfaces in the cross-scale slope fit.

Delays inject at COMPUTE vertices only — communication vertices are
where the replay engine *exposes* the delay as waiting, which is exactly
the symptom/cause split Algorithm 1's busy-time scoring must undo.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import COMP, LOOP, PSG
from repro.core.inject import vectorized_base_times
from repro.scenarios.source import StepTrace

Node = Tuple[int, int]


# ---------------------------------------------------------------------------
# target-vertex selection DSL
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VertexSel:
    """Declarative vertex pick: filter by kind/source, rank, index.

    ``rank_by``: "time" (measured base seconds, descending), "flops"
    (static FLOP count, descending) or "order" (top-level program order,
    ascending — index 0 is the first vertex of the step, the input
    pipeline's seat).  Resolution always restricts to the recorded PSG's
    top-level compute (the replay schedule's atomic units).
    """
    kinds: Tuple[str, ...] = (COMP, LOOP)
    source_contains: str = ""
    rank_by: str = "time"
    index: int = 0

    def resolve(self, psg: PSG, base: Dict[int, float]) -> int:
        tops = [v for vid in psg.children(psg.root)
                for v in (psg.vertices[vid],) if v.kind in self.kinds]
        if self.source_contains:
            hits = [v for v in tops if self.source_contains in v.source]
            tops = hits or tops               # soft filter: fall back whole
        if not tops:
            raise ValueError(f"no vertex matches {self}")
        if self.rank_by == "time":
            tops.sort(key=lambda v: -base.get(v.vid, 0.0))
        elif self.rank_by == "flops":
            tops.sort(key=lambda v: -v.flops)
        # "order": keep program order
        return tops[min(self.index, len(tops) - 1)].vid


@dataclasses.dataclass(frozen=True)
class ProcSpec:
    """Declarative culprit-process set, resolved at the target scale."""
    mode: str = "all"             # all | modrem | single | random
    stride: int = 1               # modrem: p % stride == rem
    rem: int = 0
    frac: float = 0.0             # random: fraction of procs; single: position
    count: int = 0                # random: |set| override (0: use frac)

    def resolve(self, n_procs: int, seed: int) -> np.ndarray:
        if self.mode == "all":
            return np.arange(n_procs)
        if self.mode == "modrem":
            return np.arange(n_procs)[np.arange(n_procs) % self.stride
                                      == self.rem]
        if self.mode == "single":
            return np.asarray([min(int(self.frac * n_procs),
                                   n_procs - 1)], int)
        if self.mode == "random":
            k = self.count or max(int(round(self.frac * n_procs)), 1)
            rng = np.random.default_rng(seed)
            return np.sort(rng.choice(n_procs, size=min(k, n_procs),
                                      replace=False))
        raise ValueError(f"unknown proc mode {self.mode!r}")


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultPlan:
    """A fault resolved against one (trace, PSG, scale, seed)."""
    channel: str                          # "abnormal" | "non_scalable"
    base_fn: Callable                     # vectorized (procs, vid) -> secs
    time_at_scale: Callable               # (procs, vid, n) -> secs
    inject: Dict[Node, float]
    target_vids: Tuple[int, ...]
    culprit_procs: np.ndarray             # at the target scale


def _base_table(trace: StepTrace, psg: PSG) -> np.ndarray:
    table = np.zeros(len(psg.vertices))
    for vid, t in trace.base.items():
        if 0 <= vid < table.size:
            table[vid] = t
    return table


def _ideal(table: np.ndarray, devices: int) -> Callable:
    """Ideal strong scaling anchored at the recording host's device count:
    the measured time IS the per-proc time at ``devices`` procs."""
    d = float(max(devices, 1))

    @vectorized_base_times
    def fn(procs, vid, n):
        t = table[vid] if 0 <= vid < table.size else 0.0
        return t * d / n

    return fn


def _bind(ts: Callable, n: int) -> Callable:
    @vectorized_base_times
    def fn(procs, vid):
        return ts(procs, vid, n)

    return fn


class Fault:
    """Base: subclasses override :meth:`plan`."""

    def plan(self, trace: StepTrace, psg: PSG, n_procs: int,
             seed: int) -> FaultPlan:
        raise NotImplementedError


def _delay_plan(trace: StepTrace, psg: PSG, n_procs: int, *, target: int,
                procs: np.ndarray, extra_frac: float) -> FaultPlan:
    """Additive per-proc delay at one compute vertex (abnormal channel):
    ``extra_frac`` of the ideally-scaled step time, so the injected delay
    keeps the same share of the step at every scale."""
    table = _base_table(trace, psg)
    ts = _ideal(table, trace.recorded_devices)
    extra = extra_frac * trace.step_time() * trace.recorded_devices / n_procs
    inject = {(int(p), target): extra for p in procs}
    return FaultPlan(channel="abnormal", base_fn=_bind(ts, n_procs),
                     time_at_scale=ts, inject=inject,
                     target_vids=(target,), culprit_procs=procs)


@dataclasses.dataclass(frozen=True)
class MoEImbalance(Fault):
    """Hot experts: the MoE dispatch compute runs long on a proc subset;
    the following all-to-all exposes the imbalance as wait everywhere
    else.  Ground truth is the dispatch vertex on the hot procs."""
    select: VertexSel = VertexSel(source_contains="moe.py", rank_by="time")
    procs: ProcSpec = ProcSpec("modrem", stride=16, rem=3)
    extra_frac: float = 0.5

    def plan(self, trace, psg, n_procs, seed):
        target = self.select.resolve(psg, trace.base)
        return _delay_plan(trace, psg, n_procs, target=target,
                           procs=self.procs.resolve(n_procs, seed),
                           extra_frac=self.extra_frac)


@dataclasses.dataclass(frozen=True)
class PipelineBubble(Fault):
    """One straggler stage: a single proc runs its heaviest compute long;
    the trace's collective-permute ring turns it into a pipeline bubble
    that stalls every stage behind it.  The straggler sits late in the
    ring (frac 0.9) so the wait chain from any stalled stage back to the
    culprit fits inside backtrack's path-length cap at bench scales."""
    select: VertexSel = VertexSel(rank_by="time")
    procs: ProcSpec = ProcSpec("single", frac=0.9)
    extra_frac: float = 0.6

    def plan(self, trace, psg, n_procs, seed):
        target = self.select.resolve(psg, trace.base)
        return _delay_plan(trace, psg, n_procs, target=target,
                           procs=self.procs.resolve(n_procs, seed),
                           extra_frac=self.extra_frac)


@dataclasses.dataclass(frozen=True)
class DataStall(Fault):
    """Input-pipeline stall: the FIRST compute vertex of the step (where
    host->device feeding lands) blocks a seeded random proc subset for a
    full step's worth of time — the device idles while the host feeds."""
    select: VertexSel = VertexSel(rank_by="order", index=0)
    procs: ProcSpec = ProcSpec("random", frac=0.05)
    extra_frac: float = 1.0

    def plan(self, trace, psg, n_procs, seed):
        target = self.select.resolve(psg, trace.base)
        return _delay_plan(trace, psg, n_procs, target=target,
                           procs=self.procs.resolve(n_procs, seed),
                           extra_frac=self.extra_frac)


@dataclasses.dataclass(frozen=True)
class BatchSkew(Fault):
    """Serving batch-size skew: a proc subset decodes oversized batches,
    scaling the dominant decode compute multiplicatively (imbalance, not
    a fixed delay — the skew grows with the work)."""
    select: VertexSel = VertexSel(rank_by="time")
    procs: ProcSpec = ProcSpec("modrem", stride=8, rem=1)
    factor: float = 0.9

    def plan(self, trace, psg, n_procs, seed):
        target = self.select.resolve(psg, trace.base)
        table = _base_table(trace, psg)
        ideal = _ideal(table, trace.recorded_devices)
        culprit = self.procs.resolve(n_procs, seed)
        factor = self.factor
        spec = self.procs

        @vectorized_base_times
        def ts(procs, vid, n):
            t = ideal(procs, vid, n)
            if vid == target:
                hot = np.isin(np.asarray(procs), spec.resolve(int(n), seed))
                return t * (1.0 + factor * hot)
            return t

        return FaultPlan(channel="abnormal", base_fn=_bind(ts, n_procs),
                         time_at_scale=ts, inject={},
                         target_vids=(target,), culprit_procs=culprit)


@dataclasses.dataclass(frozen=True)
class SerialFraction(Fault):
    """Amdahl: ``frac`` of the heaviest compute vertex does not
    parallelize.  Surfaces in the cross-scale log-log slope fit (the
    non-scalable channel); every process is equally guilty."""
    select: VertexSel = VertexSel(rank_by="time")
    frac: float = 0.55

    def plan(self, trace, psg, n_procs, seed):
        target = self.select.resolve(psg, trace.base)
        table = _base_table(trace, psg)
        d = float(max(trace.recorded_devices, 1))
        frac = self.frac

        @vectorized_base_times
        def ts(procs, vid, n):
            t = table[vid] if 0 <= vid < table.size else 0.0
            if vid == target:
                return t * (frac + (1.0 - frac) * d / n)
            return t * d / n

        return FaultPlan(channel="non_scalable", base_fn=_bind(ts, n_procs),
                         time_at_scale=ts, inject={},
                         target_vids=(target,),
                         culprit_procs=np.arange(n_procs))


FAULT_KINDS = {
    "moe_imbalance": MoEImbalance,
    "pipeline_bubble": PipelineBubble,
    "data_stall": DataStall,
    "batch_skew": BatchSkew,
    "serial_fraction": SerialFraction,
}
