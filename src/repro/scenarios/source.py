"""Real-model step traces: the scenario bank's PPG source.

A :class:`StepTrace` is one profiled jitted step (train or decode) of a
model from the zoo, captured once by ``python -m repro.scenarios.record``
(which needs jax) and committed as JSON under ``scenarios/traces/`` so the
bank itself replays WITHOUT jax — the same seam as ``detect``'s numpy
fallback.  A trace holds:

  * the contracted PSG from :class:`~repro.core.profiler.GraphProfiler`
    over the real step function (sampled timing, state kept resident
    between steps),
  * per-vertex mean base times (seconds, measured on the recording host),
  * the collective mix of the step's compiled sharded HLO
    (:func:`~repro.core.hlo_walk.analyze_hlo` over a
    ``launch.shardings.build_cell`` lowering), aggregated per kind with
    the replica-group LAYOUT recorded as a scale-free pattern.

Replica groups are recorded on a handful of host devices but scenarios
replay at 512-2048 procs, so groups are not stored literally: each
collective keeps a pattern — ``consecutive`` runs of fixed size (a model/
tensor axis), ``strided`` groups (a data axis laid out across the model
axis), or ``global`` — and :func:`instantiate_psg` re-materializes the
matching groups at the target scale, appending one Comm vertex per
collective to a fresh copy of the PSG.  ``ring`` patterns materialize
p2p pairs instead (pipeline-style neighbor exchange).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import COMM, PSG

TRACE_DIR = Path(__file__).resolve().parent / "traces"

PATTERNS = ("consecutive", "strided", "global", "ring")


@dataclasses.dataclass(frozen=True)
class GroupPattern:
    """Scale-free replica-group layout.

    ``consecutive``: groups are runs ``[a, a+1, ..., a+size-1]`` — the
    model/tensor axis of a row-major (data, model) mesh.  ``strided``:
    ``size`` groups of stride ``size`` — the data axis of the same mesh.
    ``global``: one group over every process.  ``ring``: ordered p2p
    pairs ``(p, (p+1) % n)`` — neighbor exchange, not a replica group.
    """
    layout: str                  # one of PATTERNS
    size: int = 1                # consecutive: group size; strided: stride

    def groups_at(self, n_procs: int) -> List[List[int]]:
        if self.layout == "consecutive":
            g = max(int(self.size), 1)
            return [list(range(s, min(s + g, n_procs)))
                    for s in range(0, n_procs, g)]
        if self.layout == "strided":
            s = max(int(self.size), 1)
            return [list(range(r, n_procs, s)) for r in range(min(s, n_procs))]
        if self.layout == "global":
            return [list(range(n_procs))]
        raise ValueError(f"{self.layout!r} has no replica groups")

    def pairs_at(self, n_procs: int) -> List[Tuple[int, int]]:
        if self.layout != "ring":
            raise ValueError(f"{self.layout!r} has no p2p pairs")
        return [(p, (p + 1) % n_procs) for p in range(n_procs)]


def classify_groups(groups: Sequence[Sequence[int]],
                    n_devices: int) -> GroupPattern:
    """Recorded replica groups -> scale-free :class:`GroupPattern`.

    Recognizes the two layouts a row-major (data, model) mesh produces —
    consecutive runs (model axis) and constant-stride combs (data axis);
    anything else degrades to ``global`` (safe: a global barrier is the
    conservative over-approximation for wait propagation).
    """
    gs = [list(g) for g in groups if len(g)]
    if not gs or sum(len(g) for g in gs) >= n_devices and len(gs) == 1:
        return GroupPattern("global")
    sizes = {len(g) for g in gs}
    if len(sizes) == 1:
        size = sizes.pop()
        if all(g == list(range(g[0], g[0] + size)) for g in gs):
            return GroupPattern("consecutive", size)
        stride = len(gs)
        if size > 1 and all(
                g == list(range(g[0], g[0] + stride * size, stride))
                for g in gs):
            return GroupPattern("strided", stride)
    return GroupPattern("global")


@dataclasses.dataclass
class CollectiveSpec:
    """One aggregated collective of the recorded step's compiled HLO."""
    kind: str                    # all-reduce | all-to-all | all-gather | ...
    bytes: float                 # summed payload across instances
    count: int                   # instances aggregated
    pattern: GroupPattern
    order: int = 0               # first-occurrence rank in the HLO program

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CollectiveSpec":
        d = dict(d)
        d["pattern"] = GroupPattern(**d["pattern"])
        return cls(**d)


@dataclasses.dataclass
class StepTrace:
    """One recorded jitted step: PSG + base times + collective mix."""
    name: str
    arch: str
    kind: str                    # train | decode | prefill
    psg: PSG
    base: Dict[int, float]       # vid -> mean seconds on the recording host
    collectives: List[CollectiveSpec]
    recorded_devices: int = 1
    mesh: Dict[str, int] = dataclasses.field(default_factory=dict)
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name, "arch": self.arch, "kind": self.kind,
            "recorded_devices": self.recorded_devices, "mesh": self.mesh,
            "meta": self.meta,
            "base": {str(k): v for k, v in sorted(self.base.items())},
            "collectives": [c.to_dict() for c in self.collectives],
            "psg": json.loads(self.psg.to_json()),
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "StepTrace":
        raw = json.loads(text)
        return cls(
            name=raw["name"], arch=raw["arch"], kind=raw["kind"],
            psg=PSG.from_json(json.dumps(raw["psg"])),
            base={int(k): float(v) for k, v in raw["base"].items()},
            collectives=[CollectiveSpec.from_dict(c)
                         for c in raw["collectives"]],
            recorded_devices=int(raw.get("recorded_devices", 1)),
            mesh=dict(raw.get("mesh", {})),
            meta=dict(raw.get("meta", {})))

    def step_time(self) -> float:
        """Sum of measured top-level vertex times (seconds)."""
        tops = self.psg.children(self.psg.root)
        return sum(self.base.get(v, 0.0) for v in tops)


def list_traces() -> List[str]:
    return sorted(p.stem for p in TRACE_DIR.glob("*.json"))


def load_trace(name: str) -> StepTrace:
    path = TRACE_DIR / f"{name}.json"
    if not path.exists():
        raise FileNotFoundError(
            f"no committed trace {name!r} (have: {list_traces()}); "
            f"record with `python -m repro.scenarios.record`")
    return StepTrace.from_json(path.read_text())


def save_trace(trace: StepTrace) -> Path:
    TRACE_DIR.mkdir(parents=True, exist_ok=True)
    path = TRACE_DIR / f"{trace.name}.json"
    path.write_text(trace.to_json())
    return path


def instantiate_psg(trace: StepTrace, n_procs: int,
                    anchor: Optional[int] = None) -> PSG:
    """Fresh PSG for one scenario run: copy + collectives at target scale.

    Returns a deep copy of the recorded PSG (scenarios mutate meta /
    append vertices; the cached trace must stay pristine) with one Comm
    vertex appended per recorded :class:`CollectiveSpec`, replica groups
    or ring pairs re-materialized for ``n_procs`` processes, in HLO
    program order after every recorded compute vertex — the step-end
    exposure chain a propagated delay surfaces through.  ``anchor``
    (default: the LAST measured top-level vertex — the step's compute
    tail, the true immediate dependence of a step-end collective) gets a
    data edge to every appended Comm vertex, so backtracking crosses
    from a wait symptom into the profiler PSG's real data-edge chain.
    """
    psg = PSG.from_json(trace.psg.to_json())
    if anchor is None:
        tops = [v for v in psg.children(psg.root)
                if trace.base.get(v, 0.0) > 0.0]
        anchor = tops[-1] if tops else None
    prev_comm = None
    for spec in sorted(trace.collectives, key=lambda c: c.order):
        per_bytes = spec.bytes / max(spec.count, 1)
        v = psg.new_vertex(COMM, spec.kind, parent=psg.root,
                           source=f"trace:{trace.name}")
        v.comm_kind = spec.kind.replace("-", "_")
        v.comm_bytes = float(per_bytes)
        if spec.pattern.layout == "ring":
            v.p2p_pairs = spec.pattern.pairs_at(n_procs)
        else:
            v.meta["replica_groups"] = spec.pattern.groups_at(n_procs)
        v.meta["pattern"] = dataclasses.asdict(spec.pattern)
        psg.add_edge(psg.root, v.vid, "control")
        if anchor is not None:
            psg.add_edge(anchor, v.vid, "data")
        if prev_comm is not None:
            # the step-end collectives are a dependence CHAIN: a late
            # arriver at collective k is late because of collective k-1
            # (e.g. a ring bubble), and the walk must be able to descend
            # into it rather than jump straight to compute
            psg.add_edge(prev_comm, v.vid, "data")
        prev_comm = v.vid
    return psg
