"""The ground-truth scenario bank (paper §VI evaluation methodology).

A :class:`Scenario` pairs one committed real-model :class:`StepTrace`
with one declarative :class:`~repro.scenarios.faults.Fault` and a
:class:`GroundTruth` stating what a correct diagnosis must report — the
root-cause vertex (by construction, the fault's injection site), the
culprit process set, the expected vertex kinds, and the accuracy floors
the bench asserts.  :meth:`Scenario.run` executes the full pipeline —
instantiate the PSG at the target scale, resolve the fault, replay with
the array engine, detect (numpy or jax backend), backtrack, rank root
causes — and returns a :class:`ScenarioResult` that
:mod:`repro.scenarios.score` turns into precision/recall/path-hit-rate.

Everything here is jax-free: traces are committed JSON, the replay
engine is numpy, and ``backend="jax"`` only routes the detection math
through ``detect``'s backend seam when jax is importable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backtrack import Path, backtrack, root_causes
from repro.core.detect import (Abnormal, NonScalable, detect_abnormal,
                               detect_non_scalable)
from repro.core.graph import PPG, PSG
from repro.core.inject import simulate, simulate_series
from repro.scenarios.faults import (BatchSkew, DataStall, Fault, FaultPlan,
                                    MoEImbalance, PipelineBubble, ProcSpec,
                                    SerialFraction)
from repro.scenarios.source import (CollectiveSpec, GroupPattern, StepTrace,
                                    instantiate_psg, load_trace)

Node = Tuple[int, int]

_TRACE_CACHE: Dict[str, StepTrace] = {}


def _trace(name: str) -> StepTrace:
    if name not in _TRACE_CACHE:
        _TRACE_CACHE[name] = load_trace(name)
    return _TRACE_CACHE[name]


@dataclasses.dataclass(frozen=True)
class GroundTruth:
    """What a correct diagnosis reports, and the floors the bench asserts.

    The root-cause VERTICES are resolved by the fault plan (its injection
    targets); ``expect_kinds`` sanity-checks their PSG kinds.  ``eval_k``
    is the root-cause report depth scored against (0: exactly the number
    of truth vertices — precision@k with k = |truth|).  ``procs_matter``
    is False on the non-scalable channel, where every process shares the
    serial fraction equally.
    """
    expect_kinds: Tuple[str, ...] = ("Comp", "Loop")
    procs_matter: bool = True
    eval_k: int = 0
    min_precision: float = 0.8
    min_recall: float = 0.8
    min_path_hit: float = 0.8


@dataclasses.dataclass
class ScenarioResult:
    """One end-to-end run: pipeline outputs + resolved ground truth."""
    scenario: str
    n_procs: int
    backend: str
    seed: int
    channel: str
    psg: PSG
    ppg: PPG
    non_scalable: List[NonScalable]
    abnormal: List[Abnormal]
    paths: List[Path]
    reported: List[Tuple[Node, str, str]]     # root_causes output
    truth_vids: Tuple[int, ...]
    truth_procs: np.ndarray
    truth: GroundTruth

    def key(self) -> tuple:
        """Deterministic digest for reproducibility checks: every
        flagged/reported identity, bit-exact."""
        return (tuple((a.vid, a.proc, a.time) for a in self.abnormal),
                tuple((d.vid, d.slope) for d in self.non_scalable),
                tuple(tuple(p.nodes) for p in self.paths),
                tuple(n for n, _, _ in self.reported))


def _ladder(n_procs: int) -> List[int]:
    """Cross-scale series for the non-scalable channel: three octaves up
    to the target scale."""
    return [max(n_procs // 8, 2), max(n_procs // 4, 2),
            max(n_procs // 2, 2), n_procs]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible scaling-loss case: trace x fault x ground truth."""
    name: str
    trace: str
    fault: Fault
    truth: GroundTruth = GroundTruth()
    extra_collectives: Tuple[CollectiveSpec, ...] = ()
    seed: int = 0
    abnorm_thd: float = 1.3
    # abnormal report depth: wide enough that true-cause flags survive
    # next to the comm-wait symptom flags that co-rank with them
    top_k: int = 64

    def build(self, n_procs: int, seed: Optional[int] = None
              ) -> Tuple[PSG, FaultPlan, StepTrace]:
        """Instantiate the PSG at ``n_procs`` and resolve the fault."""
        trace = _trace(self.trace)
        if self.extra_collectives:
            trace = dataclasses.replace(
                trace, collectives=list(trace.collectives)
                + list(self.extra_collectives))
        psg = instantiate_psg(trace, n_procs)
        plan = self.fault.plan(trace, psg, n_procs,
                               self.seed if seed is None else seed)
        return psg, plan, trace

    def run(self, n_procs: int, *, backend: str = "numpy",
            seed: Optional[int] = None,
            proc_mask: Optional[np.ndarray] = None) -> ScenarioResult:
        seed = self.seed if seed is None else seed
        psg, plan, trace = self.build(n_procs, seed)
        if plan.channel == "non_scalable":
            series = simulate_series(psg, _ladder(n_procs),
                                     plan.time_at_scale, seed=seed)
            ppg = series[n_procs]
            ns = detect_non_scalable(series, backend=backend,
                                     proc_mask=proc_mask)
        else:
            ppg = simulate(psg, n_procs, plan.base_fn, inject=plan.inject,
                           seed=seed).ppg
            ns = []
        ab = detect_abnormal(ppg, abnorm_thd=self.abnorm_thd,
                             top_k=self.top_k, backend=backend,
                             proc_mask=proc_mask)
        paths = backtrack(ppg, ns, ab)
        k = self.truth.eval_k or max(len(plan.target_vids), 1)
        reported = root_causes(paths, psg, top_k=k, ppg=ppg)
        return ScenarioResult(
            scenario=self.name, n_procs=n_procs, backend=backend, seed=seed,
            channel=plan.channel, psg=psg, ppg=ppg, non_scalable=ns,
            abnormal=ab, paths=paths, reported=reported,
            truth_vids=tuple(plan.target_vids),
            truth_procs=np.asarray(plan.culprit_procs), truth=self.truth)


# ---------------------------------------------------------------------------
# the bank
# ---------------------------------------------------------------------------

_RING = CollectiveSpec(kind="collective-permute", bytes=1 << 16, count=1,
                       pattern=GroupPattern("ring"), order=-1)

SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    # Path-hit floors are per scenario: symptom paths that backtrack
    # from a step-end collective follow max-time data preds through the
    # REAL profiler edge topology, which does not always traverse an
    # early-step cause — the busy-anomaly root-cause ranking is what
    # restores precision/recall to 1.0 there (Algorithm 1's known
    # symptom/cause split).  Floors assert non-regression of the walk.
    Scenario(
        name="moe_alltoall_imbalance",
        trace="moe_train",
        fault=MoEImbalance(),
        truth=GroundTruth(expect_kinds=("Comp",), min_path_hit=0.4)),
    Scenario(
        # The recorded trace's own collective-permute ring carries the
        # bubble; no synthetic collective is appended.  Path-hit floor is
        # intentionally low: the trace's HLO orders all-reduces BEFORE
        # the ring, so the straggler's delay is absorbed (exposed as
        # wait) at the first all-reduce and the ring sees synced arrivals
        # — what it exposes at bench scale is its own O(n) sequential
        # per-pair ripple, whose flags legitimately attribute to
        # ring-tail processes.  The walk still produces the direct
        # (culprit, target) path, and busy-anomaly ranking keeps
        # precision/recall at 1.0.
        name="pipeline_bubble_straggler",
        trace="tinyllama_train",
        fault=PipelineBubble(),
        truth=GroundTruth(expect_kinds=("Comp", "Loop"),
                          min_path_hit=0.05)),
    Scenario(
        name="data_pipeline_stall",
        trace="tinyllama_train",
        fault=DataStall(),
        truth=GroundTruth(expect_kinds=("Comp", "Loop"),
                          min_path_hit=0.4)),
    Scenario(
        name="serving_batch_skew",
        trace="tinyllama_decode",
        fault=BatchSkew(),
        truth=GroundTruth(expect_kinds=("Comp", "Loop"),
                          min_path_hit=0.8)),
    Scenario(
        name="amdahl_serial_fraction",
        trace="tinyllama_train",
        fault=SerialFraction(),
        truth=GroundTruth(expect_kinds=("Comp", "Loop"),
                          procs_matter=False, min_path_hit=0.9)),
    Scenario(
        name="moe_input_stall",
        trace="moe_train",
        fault=DataStall(procs=ProcSpec("random", frac=0.08),
                        extra_frac=0.5),
        seed=7,
        truth=GroundTruth(expect_kinds=("Comp", "Loop"),
                          min_path_hit=0.5)),
)}

# the two fastest end-to-end cases: `make scenario-smoke` coverage
SMOKE_SCENARIOS = ("data_pipeline_stall", "serving_batch_skew")


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r} (have: {sorted(SCENARIOS)})")
