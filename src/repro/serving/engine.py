"""Batched serving engine: slot-based continuous batching over a fixed
(batch_slots, max_seq) cache.

One compiled decode step serves the whole slot batch; requests join/leave
slots without recompilation (shape stability is what makes this deployable:
exactly one compiled decode function).  Idle slots decode padding — masked
out at sampling time on the host.

Per-slot cache hygiene is generic across cache families (LM KV cache, SSM
state, hybrid, enc-dec): every cache leaf is either per-batch 1-D
(``length``-like, batch axis 0) or stacked (layers/sites first, batch axis
1), so slot admission zeroes axis-0/1 rows and every decode call overrides
the length leaf with the host-tracked per-slot positions.

Sampling is reproducible under any batching order: greedy, or Gumbel
argmax keyed on (request uid, position) via a counter-based PRNG — the
serving analogue of the data pipeline's determinism.

Three defenses keep token streams reproducible across engine instances:

* host-side bookkeeping arrays (``slot_pos``, ``last_token``) are
  snapshotted before entering jax — on CPU ``jnp.asarray`` may zero-copy-
  alias an aligned numpy buffer, so mutating them while the asynchronously
  dispatched decode still reads them was an alignment-dependent data race.
* the compiled decode step is shared per (ModelBundle, shapes) — XLA CPU
  compilation is not bit-deterministic, so two separately-compiled
  executables of the same program can round reductions differently, and a
  ~1e-6 logit wobble flips argmax at near-ties.  One engine, one hundred
  engines: same executable, same logits.
* sampling uses a near-tie-stable argmax: every candidate within ``_TIE_TOL``
  of the max is a tie, resolved to the lowest token id.  Executables with
  *different* shapes (a request served alone vs in a batch) can't share a
  compilation, so their residual rounding skew is absorbed by the tie
  tolerance instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelBundle

Pytree = Any

# logits gaps below this are ties (resolved to the lowest token id); must
# sit well above cross-compilation rounding skew (~1e-6 at logit scale ~3)
# and well below real logit gaps (~1e-1 for the smoke models)
_TIE_TOL = 1e-4


def _shared_jit(model: ModelBundle) -> Callable:
    """One compiled decode per ModelBundle — every engine built from the
    same bundle reuses the same executable (and its shape-keyed caches).
    Memoized on the bundle itself so the jit wrapper's lifetime is tied to
    the bundle, not pinned in a global cache."""
    fn = getattr(model, "_decode_jit", None)
    if fn is None:
        fn = jax.jit(model.decode_step)
        # ModelBundle is a frozen dataclass; store the derived memo the
        # same way frozen __init__ does
        object.__setattr__(model, "_decode_jit", fn)
    return fn


def _stable_argmax(z: np.ndarray, tol: float = _TIE_TOL) -> int:
    """Lowest index within ``tol`` of the max — invariant to sub-``tol``
    logit noise from separately-compiled executables."""
    return int(np.argmax(z >= z.max() - tol))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0           # 0 => greedy
    seed: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float = 0.0


def _batch_axis(leaf, slots: int) -> Optional[int]:
    if leaf.ndim == 1 and leaf.shape[0] == slots:
        return 0
    if leaf.ndim >= 2 and leaf.shape[1] == slots:
        return 1
    return None


class ServingEngine:
    def __init__(self, model: ModelBundle, params: Pytree, *,
                 batch_slots: int = 4, max_seq: int = 128):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(batch_slots, max_seq)
        # slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)   # tokens consumed
        self.slot_done = np.ones(batch_slots, bool)
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_t0 = np.zeros(batch_slots, np.float64)
        self.last_token = np.zeros(batch_slots, np.int32)
        self._decode = _shared_jit(model)
        self.completed: List[Result] = []
        self.decode_steps = 0

    # ------------------------------------------------------------------
    def _with_lengths(self, cache: Pytree) -> Pytree:
        """Override the per-slot length leaf with host-tracked positions.

        ``slot_pos`` is snapshotted (np.array copy): on CPU ``jnp.asarray``
        may zero-copy-alias an aligned host buffer, and the engine mutates
        ``slot_pos`` while the (asynchronously dispatched) decode still
        reads it — the alignment-dependent race behind historical
        sampling nondeterminism."""
        pos = jnp.asarray(np.array(self.slot_pos))

        def fix(leaf):
            if (hasattr(leaf, "dtype") and leaf.dtype == jnp.int32
                    and leaf.ndim == 1 and leaf.shape[0] == self.slots):
                return pos
            return leaf

        return jax.tree.map(fix, cache)

    def _clear_slot(self, cache: Pytree, slot: int) -> Pytree:
        """Zero one slot's rows in every cache leaf (state hygiene)."""
        def clear(leaf):
            ax = _batch_axis(leaf, self.slots)
            if ax is None:
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            return leaf.at[tuple(idx)].set(0)

        return jax.tree.map(clear, cache)

    def _merge_slot(self, new: Pytree, old: Pytree, slot: int) -> Pytree:
        """Take ``new``'s rows for one slot, ``old``'s rows elsewhere.

        Prefill isolation: decoding a prompt token through the shared batch
        must not advance other slots' state (harmless for KV caches whose
        writes are position-indexed, but SSM state accumulates every call).
        """
        def merge(n, o):
            ax = _batch_axis(n, self.slots)
            if ax is None:
                return n
            idx = [slice(None)] * n.ndim
            idx[ax] = slot
            return o.at[tuple(idx)].set(n[tuple(idx)])

        return jax.tree.map(merge, new, old)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request into a free slot (prefill now). False if full."""
        free = [i for i, d in enumerate(self.slot_done) if d]
        if not free:
            return False
        slot = free[0]
        self.slot_req[slot] = req
        self.slot_done[slot] = False
        self.slot_out[slot] = []
        self.slot_t0[slot] = time.perf_counter()
        self.slot_pos[slot] = 0
        self.cache = self._clear_slot(self.cache, slot)
        # token-by-token prefill through the decode path: one compiled fn
        # total, identical cache layout, exact causal semantics.
        toks = np.asarray(req.prompt, np.int32).reshape(-1)
        toks = toks[: self.max_seq - req.max_new_tokens - 1]
        logits = None
        for t in toks:
            tok_batch = np.asarray(self.last_token).reshape(-1, 1).copy()
            tok_batch[slot, 0] = t
            before = self.cache
            logits, after = self._step_model(tok_batch)
            self.cache = self._merge_slot(after, before, slot)
            self.slot_pos[slot] += 1
        if logits is not None:
            nxt = self._sample(slot, logits, int(self.slot_pos[slot]))
        else:
            nxt = int(toks[-1]) if len(toks) else 0
        self.last_token[slot] = nxt
        self.slot_out[slot].append(nxt)
        return True

    def _step_model(self, tok_batch: np.ndarray):
        cache = self._with_lengths(self.cache)
        logits, cache = self._decode(self.params, cache,
                                     jnp.asarray(tok_batch, jnp.int32))
        self.decode_steps += 1
        return logits, cache

    def _sample(self, slot: int, logits: jax.Array, position: int) -> int:
        req = self.slot_req[slot]
        row = np.asarray(logits)[slot, -1]
        if req.temperature <= 0.0:
            return _stable_argmax(row)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(req.seed), req.uid),
            position)
        g = np.asarray(jax.random.gumbel(key, row.shape))
        return _stable_argmax(row / req.temperature + g)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode step for every active slot. Returns #active."""
        active = [i for i, d in enumerate(self.slot_done) if not d]
        if not active:
            return 0
        # snapshot: last_token is updated per-slot below while the decode
        # may still be running (see _with_lengths on host-buffer aliasing)
        tok = np.array(self.last_token).reshape(-1, 1)
        logits, self.cache = self._step_model(tok)
        for i in active:
            self.slot_pos[i] += 1
            nxt = self._sample(i, logits, int(self.slot_pos[i]))
            self.last_token[i] = nxt
            self.slot_out[i].append(nxt)
            req = self.slot_req[i]
            if (len(self.slot_out[i]) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_seq - 1):
                self._finish(i)
        return len(active)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        self.completed.append(Result(
            uid=req.uid, tokens=list(self.slot_out[slot]),
            prompt_len=len(req.prompt),
            latency_s=time.perf_counter() - self.slot_t0[slot]))
        self.slot_done[slot] = True
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            max_steps: int = 10_000) -> List[Result]:
        """Serve requests to completion (continuous batching)."""
        pending = list(requests)
        steps = 0
        while (pending or not all(self.slot_done)) and steps < max_steps:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
        return sorted(self.completed, key=lambda r: r.uid)
