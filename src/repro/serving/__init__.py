from repro.serving.engine import ServingEngine, Request, Result

__all__ = ["ServingEngine", "Request", "Result"]
