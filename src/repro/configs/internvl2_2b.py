"""internvl2-2b [vlm] — InternViT + InternLM2 backbone. [arXiv:2404.16821]

The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings of shape (batch, frontend_len, d_model) prepended to the token
stream; the assigned config describes the LM backbone.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    mlp="swiglu",
    frontend_len=256,             # precomputed image patches per example
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, frontend_len=8, loss_chunk=16,
    )
