"""mamba2-130m [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                    # attention-free
    n_kv_heads=0,
    d_ff=0,                       # no MLP; SSD block only (Mamba2 design)
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,              # -> 24 SSD heads (d_inner=1536)
    ssm_chunk=64,
    conv_width=4,
    tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        vocab_size=256, loss_chunk=16,
    )
