"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,                   # MLP inside the shared attention block
    vocab_size=32000,
    mlp="gelu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,              # -> 80 SSD heads (d_inner=5120)
    ssm_chunk=64,
    conv_width=4,
    attn_every=6,                 # shared attention block every 6 layers
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        attn_every=2, loss_chunk=16,
    )
