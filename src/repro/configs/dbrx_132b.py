"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,                   # per-expert intermediate
    vocab_size=100352,
    mlp="swiglu",
    n_experts=16,
    experts_per_token=4,
    rope_theta=500000.0,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=48,
        vocab_size=256, n_experts=4, experts_per_token=2, loss_chunk=16,
    )
