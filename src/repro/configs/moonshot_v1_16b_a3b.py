"""moonshot-v1-16b-a3b [moe] — 64 experts top-6 (Moonlight-16B-A3B). [hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                    # per-expert intermediate
    vocab_size=163840,
    mlp="swiglu",
    n_experts=64,
    experts_per_token=6,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab_size=256, n_experts=8, experts_per_token=2, loss_chunk=16,
    )
