"""yi-6b [dense] — llama-arch GQA, SwiGLU. [arXiv:2403.04652]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    mlp="swiglu",
    rope_theta=5000000.0,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, loss_chunk=16,
    )
