"""Configuration system: architecture configs and input-shape configs.

Every assigned architecture gets one module in this package exposing
``CONFIG`` (the exact published numbers) and ``smoke()`` (a reduced config of
the same family for CPU tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    """Architecture hyperparameters (model topology only, no runtime knobs)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | geglu | relu2 | gelu
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 1024       # routing-group tokens (0 = one group)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64              # SSD chunk length
    conv_width: int = 4

    # --- hybrid (zamba2-style): shared attention block every k layers ---
    attn_every: int = 0

    # --- enc-dec (seamless-m4t backbone): encoder depth; n_layers = decoder ---
    enc_layers: int = 0
    # audio/vision frontends are STUBS: input_specs() provides embeddings
    frontend_len: int = 0            # frames / patches per example

    # --- dtypes ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # --- training-time knobs that affect lowering ---
    loss_chunk: int = 512            # chunked cross-entropy seq chunk
    remat: bool = True
    use_kernels: bool = False        # Pallas flash-attn / SSD-scan paths

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    # ------------------------------------------------------------------
    # Rough parameter count (for roofline MODEL_FLOPS = 6 N D).
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d = self.d_model
        h = self.resolved_head_dim() if self.n_heads else 0
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d

        def mlp_params(ff):
            gates = 3 if self.mlp in ("swiglu", "geglu") else 2
            return gates * d * ff

        if self.family == "moe":
            n_e = (self.experts_per_token if active_only else self.n_experts)
            mlp = n_e * mlp_params(self.d_ff) + d * self.n_experts  # + router
        else:
            mlp = mlp_params(self.d_ff)

        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            # in_proj (x,z,B,C,dt) + out_proj + conv + A,D
            blk = d * (2 * di + 2 * ns + self.ssm_heads) + di * d \
                + self.conv_width * (di + 2 * ns) + 2 * self.ssm_heads
            per_layer = blk + d  # + norm
        elif self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            blk = d * (2 * di + 2 * ns + self.ssm_heads) + di * d \
                + self.conv_width * (di + 2 * ns) + 2 * self.ssm_heads
            per_layer = blk + mlp + 2 * d
        else:
            per_layer = attn + mlp + 2 * d

        n_blocks = self.n_layers + self.enc_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = n_blocks * per_layer + emb + d
        if self.family == "hybrid" and self.attn_every:
            total += attn + d                      # one shared attention block
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four LM shapes shared by all 10 assigned architectures.
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic sequence mixing: run only for SSM/hybrid.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k" and arch.family not in LONG_CONTEXT_FAMILIES:
        return False, ("skip: pure full-attention arch; long_500k needs "
                       "sub-quadratic sequence mixing (DESIGN.md §5)")
    return True, ""


@dataclass(frozen=True)
class RunConfig:
    """Runtime knobs: mesh, sharding, optimization, ScalAna."""

    arch: str = "tinyllama-1.1b"
    shape: str = "train_4k"
    multi_pod: bool = False
    microbatch: int = 0              # 0 = no gradient accumulation
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    checkpoint_dir: str = ""
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    scalana: bool = True             # graph-guided profiling on/off
    scalana_sample_every: int = 16   # region-profile every K steps
    scalana_comm_sample: float = 0.1 # comm-record sampling probability
    max_loop_depth: int = 10         # paper's MaxLoopDepth
    abnorm_thd: float = 1.3          # paper's AbnormThd
    # distributed-optimization tricks
    grad_compress: bool = False      # int8 error-feedback grad compression
    step_timeout_s: float = 0.0      # straggler guard (0 = off)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
