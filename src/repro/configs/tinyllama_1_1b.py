"""tinyllama-1.1b [dense] — llama2-arch small, GQA. [arXiv:2401.02385]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    mlp="swiglu",
    rope_theta=10000.0,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, loss_chunk=16,
    )
