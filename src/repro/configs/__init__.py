"""Architecture/shape registry.

``get(name)`` returns the full published config; ``get_smoke(name)`` returns a
reduced same-family config for CPU tests.  ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ArchConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    LONG_CONTEXT_FAMILIES,
    shape_applicable,
)

_MODULES: Dict[str, str] = {
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-6b": "yi_6b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma-7b": "gemma_7b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-2b": "internvl2_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "dbrx-132b": "dbrx_132b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCHS: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


__all__ = [
    "ArchConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCHS",
    "LONG_CONTEXT_FAMILIES", "shape_applicable", "get", "get_smoke",
]
