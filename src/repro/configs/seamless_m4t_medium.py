"""seamless-m4t-medium [audio] — enc-dec transformer backbone. [arXiv:2308.11596]

The speech/text frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings of shape (batch, frontend_len, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                  # decoder layers
    enc_layers=12,                # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp="gelu",
    frontend_len=1024,            # precomputed audio frames per example
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, frontend_len=16, loss_chunk=16,
    )
