"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state is a pytree congruent with params, so the same
NamedShardings shard it (ZeRO-1 for free under FSDP rules).  All math in
f32 regardless of param dtype (mixed-precision master-weights convention
is the caller's choice via ``mu_dtype``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array          # i32 scalar
    mu: Pytree               # first moment
    nu: Pytree               # second moment


def adamw_init(params: Pytree, mu_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, mu_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads: Pytree, state: AdamWState, params: Pytree, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0
                 ) -> Tuple[Pytree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if max_grad_norm and max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, stepf)
    bc2 = 1.0 - jnp.power(b2, stepf)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p in
            zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    new_state = AdamWState(step=step, mu=new_m, nu=new_v)
    metrics = {"grad_norm": gnorm,
               "param_norm": global_norm(params),
               "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, new_state, metrics
