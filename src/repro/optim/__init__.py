from repro.optim.adamw import (
    AdamWState, adamw_init, adamw_update, global_norm, clip_by_global_norm,
)
from repro.optim.schedule import warmup_cosine, warmup_linear, constant
from repro.optim.compress import (
    compress_grads, decompress_grads, error_feedback_update,
)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update",
    "global_norm", "clip_by_global_norm",
    "warmup_cosine", "warmup_linear", "constant",
    "compress_grads", "decompress_grads", "error_feedback_update",
]
