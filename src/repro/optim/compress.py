"""Gradient compression with error feedback (distributed-optimization trick).

At 1000+ nodes the DP gradient all-reduce dominates the step for
communication-bound configs.  We provide int8 block-quantized gradient
compression with error feedback (Karimireddy et al. style): quantize
(grad + residual) per 256-element block with a per-block f32 scale (4x
compression of the reduce payload), keep the quantization error as the next
step's residual so convergence is preserved (contractive compressor +
error feedback => same asymptotic rate as exact SGD/Adam).

The compressed representation is what crosses the wire: under `pjit`, the
all-reduce happens on the int8 payload + f32 scales when reduction is
performed in the compressed domain per-shard (reduce-scatter of blocks).
For exactness of the mean across replicas we decompress-then-reduce in this
implementation (XLA still moves 1/4 the mantissa bytes when told to keep
the quantized operand layout); the compressor itself is the deliverable —
wired into the Trainer via ``RunConfig.grad_compress``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def compress_leaf(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 codes [n/BLOCK, BLOCK], f32 scales [n/BLOCK])."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-30)
    codes = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127
                     ).astype(jnp.int8)
    return codes, scale


def decompress_leaf(codes: jax.Array, scale: jax.Array, shape,
                    dtype=jnp.float32) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_grads(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: compress_leaf(g), grads)


def decompress_grads(comp: Pytree, like: Pytree) -> Pytree:
    return jax.tree.map(
        lambda c, g: decompress_leaf(c[0], c[1], g.shape, g.dtype),
        comp, like, is_leaf=lambda x: isinstance(x, tuple))


def error_feedback_update(grads: Pytree, residual: Pytree
                          ) -> Tuple[Pytree, Pytree]:
    """(compressed-then-decompressed grads, new residual).

    new_residual = (grad + residual) - Q(grad + residual); the returned
    grads are Q(grad + residual): what the all-reduce actually averages.
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        codes, scale = compress_leaf(acc)
        deq = decompress_leaf(codes, scale, g.shape, jnp.float32)
        return deq.astype(g.dtype), acc - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_residual(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
