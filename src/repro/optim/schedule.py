"""Learning-rate schedules as pure ``step -> lr`` functions (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def warmup_linear(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        frac = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        decay = 1.0 - (1.0 - final_frac) * jnp.clip(frac, 0.0, 1.0)
        return lr * jnp.where(s < warmup_steps, warm, decay)
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        frac = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.clip(frac, 0.0, 1.0)))
        decay = final_frac + (1.0 - final_frac) * cos
        return lr * jnp.where(s < warmup_steps, warm, decay)
    return f
