"""Logical-axis sharding rules.

Models are written mesh-agnostic against *logical* axis names
('batch', 'embed', 'q_features', 'vocab', 'experts', ...).  A launcher
installs a rule table + mesh via ``use_rules``; ``logical_constraint`` then
applies ``with_sharding_constraint`` and ``spec_for`` resolves parameter
PartitionSpecs.  Without an active context everything is a no-op, so unit
tests and single-device runs never touch the mesh machinery.

Rules map logical name -> mesh axis (str), tuple of mesh axes, or None.
A logical dim is only sharded if its size is divisible by the mesh axis
product (GSPMD padding is legal but wasteful; we opt out explicitly —
e.g. yi-6b's 4 KV heads on a 16-way model axis stay replicated).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Rule = Union[str, Tuple[str, ...], None]

# Default logical rules for the production 2D/3D mesh.
DEFAULT_RULES: Dict[str, Rule] = {
    "batch": ("pod", "data"),
    "seq": None,
    "res_seq": None,           # residual-stream seq; "model" under SP (§Perf it.3)
    "kv_seq": None,            # overridden to "data" for long-context serving
    "embed": "data",           # FSDP dimension for params
    "q_features": "model",     # n_heads * head_dim
    "kv_features": "model",    # n_kv_heads * head_dim
    "heads": "model",          # per-head activation axis
    "kv_heads": "model",
    "mlp": "model",            # d_ff
    "vocab": "model",
    "experts": "model",        # EP
    "ssm_inner": "model",      # d_inner of SSD blocks
    "ssm_heads": "model",
    "ssm_pdim": "model",       # SSD head_dim fallback when H % model != 0
    "layers": None,
    "frontend": None,
    "state": None,
    "conv": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Rule] = {}
        self.options: Dict[str, bool] = {}


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[Dict[str, Rule]] = None,
              options: Optional[Dict[str, bool]] = None):
    """Install mesh + logical rules (+ optimization options) for the region.

    Options (all default off — the paper-faithful baseline):
      * ``gather_weights`` — ZeRO-3-style FSDP: weights stay sharded on
        'data' in HBM but are all-gathered at their matmul (a per-layer
        weight AG of MBs replaces per-layer activation all-reduces of
        GBs; see EXPERIMENTS.md §Perf iteration 1).
    """
    prev = (_CTX.mesh, _CTX.rules, _CTX.options)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES if rules is None else rules)
    _CTX.options = dict(options or {})
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.options = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def option(name: str) -> bool:
    return bool(_CTX.options.get(name, False))


def weight_constraint(w: jax.Array, *logical_axes: Optional[str]
                      ) -> jax.Array:
    """FSDP gather-at-use point for a weight matrix.

    No-op unless the ``gather_weights`` option is on; then the weight's
    'embed' (FSDP-storage) dim is constrained to be replicated right
    before the matmul, so GSPMD all-gathers the small weight shards
    instead of all-reducing large partial-sum activations."""
    if not option("gather_weights"):
        return w
    axes = tuple(None if a == "embed" else a for a in logical_axes)
    return logical_constraint(w, *axes)


def _mesh_axes_of(rule: Rule, mesh: Mesh) -> Tuple[str, ...]:
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in mesh.axis_names)


def _axis_product(axes: Tuple[str, ...], mesh: Mesh) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p


def resolve_axis(logical: Optional[str], dim_size: int,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Dict[str, Rule]] = None) -> Rule:
    """Mesh axes for one logical dim, or None (incl. non-divisible opt-out)."""
    mesh = mesh or _CTX.mesh
    rules = rules if rules is not None else _CTX.rules
    if mesh is None or logical is None:
        return None
    axes = _mesh_axes_of(rules.get(logical), mesh)
    if not axes:
        return None
    if dim_size % _axis_product(axes, mesh) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_for(logical_axes: Sequence[Optional[str]],
             shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, Rule]] = None) -> PartitionSpec:
    """PartitionSpec for a value with the given logical axes and shape."""
    used: set = set()
    parts = []
    for name, size in zip(logical_axes, shape):
        ax = resolve_axis(name, size, mesh, rules)
        # one mesh axis may shard at most one dim
        flat = () if ax is None else ((ax,) if isinstance(ax, str) else tuple(ax))
        if any(a in used for a in flat):
            ax = None
            flat = ()
        used.update(flat)
        parts.append(ax)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def logical_constraint(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without context."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Dict[str, Rule]] = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    assert mesh is not None, "sharding_for requires a mesh"
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh, rules))
