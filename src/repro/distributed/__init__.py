from repro.distributed.axes import (
    DEFAULT_RULES,
    logical_constraint,
    resolve_axis,
    sharding_for,
    spec_for,
    use_rules,
)

__all__ = [
    "DEFAULT_RULES", "logical_constraint", "resolve_axis", "sharding_for",
    "spec_for", "use_rules",
]
