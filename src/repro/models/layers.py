"""Shared neural-net primitives: norms, rotary embeddings, MLP variants."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint, weight_constraint
from repro.models.params import P


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rotary_embedding(positions: jax.Array, head_dim: int, theta: float,
                     dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """(positions...) -> cos/sin of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, n_heads, head_dim); cos/sin: (..., S, head_dim//2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :].astype(jnp.float32)   # broadcast over heads
    s = sin[..., None, :].astype(jnp.float32)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, P]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": P((d, f), ("embed", "mlp")),
            "w_up": P((d, f), ("embed", "mlp")),
            "w_down": P((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": P((d, f), ("embed", "mlp")),
        "w_down": P((f, d), ("mlp", "embed")),
    }


def mlp_apply(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    w_up = weight_constraint(p["w_up"], "embed", "mlp")
    if cfg.mlp == "swiglu":
        w_gate = weight_constraint(p["w_gate"], "embed", "mlp")
        h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    elif cfg.mlp == "geglu":
        w_gate = weight_constraint(p["w_gate"], "embed", "mlp")
        h = jax.nn.gelu(x @ w_gate, approximate=True) * (x @ w_up)
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(x @ w_up))
    else:  # gelu
        h = jax.nn.gelu(x @ w_up, approximate=True)
    h = logical_constraint(h, "batch", "seq", "mlp")
    return h @ weight_constraint(p["w_down"], "mlp", "embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding with chunked cross-entropy
# ---------------------------------------------------------------------------

def embed_specs(cfg: ArchConfig) -> Dict[str, P]:
    specs = {"embedding": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                            init="embed")}
    if not cfg.tie_embeddings:
        specs["unembed"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return specs


def embed_tokens(p: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    # res_seq: sharded on 'model' under sequence parallelism (block
    # boundaries only — attention/MLP interiors keep heads/mlp on model)
    return logical_constraint(x, "batch", "res_seq", "embed")


def unembed_matrix(p: Dict[str, jax.Array]) -> jax.Array:
    if "unembed" in p:
        w = p["unembed"]
    else:
        w = p["embedding"].T
    return weight_constraint(w, "embed", "vocab")


def logits_for(p: Dict[str, jax.Array], h: jax.Array) -> jax.Array:
    logits = h @ unembed_matrix(p)
    return logical_constraint(logits, "batch", "seq", "vocab")


def chunked_cross_entropy(p: Dict[str, jax.Array], hidden: jax.Array,
                          labels: jax.Array, mask: jax.Array,
                          chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing (B, S, V): scan over seq chunks.

    Returns (sum_loss, sum_count) as float32; caller divides.
    The scan produces a PSG Loop vertex ("loss loop") and keeps the logits
    working set to (B, chunk, V) — the key memory-term optimization for
    256k-vocab architectures (DESIGN.md §4).
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk
    w = unembed_matrix(p)

    def one(h_c, y_c, m_c):
        logits = (h_c @ w).astype(jnp.float32)
        logits = logical_constraint(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        losses = (lse - picked) * m_c
        return jnp.sum(losses), jnp.sum(m_c)

    if n > 0:
        hs = hidden[:, : n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
        ys = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
        ms = mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

        def body(carry, xs):
            h_c, y_c, m_c = xs
            l, c = one(h_c, y_c, m_c)
            return (carry[0] + l, carry[1] + c), None

        (loss, count), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys, ms))
    else:
        loss = jnp.float32(0.0)
        count = jnp.float32(0.0)
    if rem:
        l, c = one(hidden[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
        loss, count = loss + l, count + c
    return loss, count
