"""Uniform model API: ``build_model(cfg)`` -> ``ModelBundle``.

Every architecture family exposes the same five entry points (init,
train_loss, prefill, decode_step, init_cache) plus abstract input specs so
the launcher, trainer, serving engine, dry-run and ScalAna all work over any
assigned architecture unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.params import (
    Specs,
    abstract_params,
    init_params,
    param_count,
    param_specs_tree,
)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    specs: Specs
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., Tuple[jax.Array, Any]]
    decode_step: Callable[..., Tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]
    cache_specs: Callable[[int, int], Any]

    def abstract_params(self):
        return abstract_params(self.specs, self.cfg.pdtype())

    def param_partition_specs(self):
        return param_specs_tree(self.specs)

    def param_count(self) -> int:
        return param_count(self.specs)

    # ------------------------------------------------------------------
    # Abstract inputs for one (arch x shape) cell — used by the dry-run.
    # Token batches carry S+1 tokens for train (inputs/labels shift).
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = jnp.int32
        emb = cfg.cdtype()
        if shape.kind == "train":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), tok)}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), emb)
            if cfg.family == "vlm":
                batch["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), emb)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), emb)
            if cfg.family == "vlm":
                batch["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), emb)
            return batch
        # decode: one token + primed cache of length S
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), tok),
            "cache": self.cache_specs(B, S),
        }

    def input_logical_axes(self, shape: ShapeConfig) -> Dict[str, Any]:
        """Logical axes matching input_specs (resolved by the launcher)."""
        cfg = self.cfg
        axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
        if shape.kind != "decode":
            if cfg.family == "encdec":
                axes["frames"] = ("batch", "frontend", "embed")
            if cfg.family == "vlm":
                axes["patches"] = ("batch", "frontend", "embed")
            return axes
        cache_ax = jax.tree.map(lambda _: None, self.cache_specs(1, 2))
        kv_axes = ("layers", "batch", "kv_seq", "kv_heads", None)
        if cfg.family in ("dense", "moe", "vlm"):
            cache_ax = transformer.LMCache(
                type(cache_ax.kv)(kv_axes, kv_axes, ("batch",)))
        elif cfg.family == "encdec":
            cross = ("layers", "batch", "frontend", "kv_heads", None)
            cache_ax = encdec.EncDecCache(
                type(cache_ax.self_kv)(kv_axes, kv_axes, ("batch",)),
                cross, cross)
        elif cfg.family == "ssm":
            cache_ax = ssm_lm.SSMCache(
                type(cache_ax.ssm)(("layers", "batch", None, "ssm_inner"),
                                   ("layers", "batch", "ssm_heads", None, None)),
                ("batch",))
        elif cfg.family == "hybrid":
            site_kv = (None, "batch", "kv_seq", "kv_heads", None)
            cache_ax = hybrid.HybridCache(
                type(cache_ax.ssm)(("layers", "batch", None, "ssm_inner"),
                                   ("layers", "batch", "ssm_heads", None, None)),
                site_kv, site_kv, ("batch",))
        return {"tokens": ("batch", None), "cache": cache_ax}


def build_model(cfg: ArchConfig, moe_strategy: str = "einsum") -> ModelBundle:
    pdt, cdt = cfg.pdtype(), cfg.cdtype()
    if cfg.family in ("dense", "moe", "vlm"):
        specs = transformer.lm_specs(cfg)
        return ModelBundle(
            cfg=cfg, specs=specs,
            init=lambda key: init_params(specs, key, pdt),
            train_loss=functools.partial(transformer.train_loss, cfg,
                                         moe_strategy=moe_strategy),
            prefill=functools.partial(transformer.prefill, cfg),
            decode_step=functools.partial(transformer.decode_step, cfg),
            init_cache=lambda b, s: transformer.init_cache(cfg, b, s, cdt),
            cache_specs=lambda b, s: transformer.cache_specs(cfg, b, s, cdt),
        )
    if cfg.family == "ssm":
        specs = ssm_lm.ssm_lm_specs(cfg)
        return ModelBundle(
            cfg=cfg, specs=specs,
            init=lambda key: init_params(specs, key, pdt),
            train_loss=functools.partial(ssm_lm.train_loss, cfg),
            prefill=functools.partial(ssm_lm.prefill, cfg),
            decode_step=functools.partial(ssm_lm.decode_step, cfg),
            init_cache=lambda b, s: ssm_lm.init_cache(cfg, b, s, cdt),
            cache_specs=lambda b, s: ssm_lm.cache_specs(cfg, b, s, cdt),
        )
    if cfg.family == "hybrid":
        specs = hybrid.hybrid_specs(cfg)
        return ModelBundle(
            cfg=cfg, specs=specs,
            init=lambda key: init_params(specs, key, pdt),
            train_loss=functools.partial(hybrid.train_loss, cfg),
            prefill=functools.partial(hybrid.prefill, cfg),
            decode_step=functools.partial(hybrid.decode_step, cfg),
            init_cache=lambda b, s: hybrid.init_cache(cfg, b, s, cdt),
            cache_specs=lambda b, s: hybrid.cache_specs(cfg, b, s, cdt),
        )
    if cfg.family == "encdec":
        specs = encdec.encdec_specs(cfg)
        return ModelBundle(
            cfg=cfg, specs=specs,
            init=lambda key: init_params(specs, key, pdt),
            train_loss=functools.partial(encdec.train_loss, cfg),
            prefill=functools.partial(encdec.prefill, cfg),
            decode_step=functools.partial(encdec.decode_step, cfg),
            init_cache=lambda b, s: encdec.init_cache(cfg, b, s, cdt),
            cache_specs=lambda b, s: encdec.cache_specs(cfg, b, s, cdt),
        )
    raise ValueError(f"unknown family {cfg.family!r}")
