"""Encoder-decoder transformer backbone (seamless-m4t family).

The modality frontend is a stub: the encoder consumes precomputed frame
embeddings (B, T, D).  The decoder is a causal transformer with
cross-attention; decode caches both self-attn KV and per-layer projected
cross-attn KV of the encoder output.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint
from repro.models import attention as attn
from repro.models.layers import (
    chunked_cross_entropy,
    embed_specs,
    embed_tokens,
    logits_for,
    mlp_apply,
    mlp_specs,
    rms_norm,
)
from repro.models.params import P, Specs
from repro.models.transformer import stack_specs


def encdec_specs(cfg: ArchConfig) -> Specs:
    enc_layer = {
        "attn_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "attn": attn.attention_specs(cfg),
        "mlp_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "mlp": mlp_specs(cfg),
    }
    dec_layer = {
        "self_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "self_attn": attn.attention_specs(cfg),
        "cross_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "cross_attn": attn.attention_specs(cfg),
        "mlp_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "mlp": mlp_specs(cfg),
    }
    return {
        "embed": embed_specs(cfg),
        "encoder": stack_specs(enc_layer, cfg.enc_layers),
        "decoder": stack_specs(dec_layer, cfg.n_layers),
        "enc_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "final_norm": P((cfg.d_model,), ("embed",), init="zeros"),
    }


def encode(cfg: ArchConfig, params: Dict[str, Any],
           frames: jax.Array) -> jax.Array:
    """frames: (B, T, D) precomputed embeddings -> encoder output (B, T, D)."""
    def block(x, p):
        h = x + attn.attention_train(cfg, p["attn"],
                                     rms_norm(x, p["attn_norm"], cfg.norm_eps),
                                     causal=False)
        out = h + mlp_apply(cfg, p["mlp"],
                            rms_norm(h, p["mlp_norm"], cfg.norm_eps))
        return logical_constraint(out, "batch", "res_seq", "embed")

    blk = jax.checkpoint(block) if cfg.remat else block

    def body(carry, p):
        return blk(carry, p), None

    h, _ = jax.lax.scan(body, frames, params["encoder"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block_train(cfg: ArchConfig, enc_out: jax.Array, x: jax.Array,
                     p: Dict[str, Any]) -> jax.Array:
    h = x + attn.attention_train(cfg, p["self_attn"],
                                 rms_norm(x, p["self_norm"], cfg.norm_eps))
    h = h + attn.cross_attention_train(
        cfg, p["cross_attn"], rms_norm(h, p["cross_norm"], cfg.norm_eps),
        enc_out)
    out = h + mlp_apply(cfg, p["mlp"], rms_norm(h, p["mlp_norm"], cfg.norm_eps))
    return logical_constraint(out, "batch", "res_seq", "embed")


def train_loss(cfg: ArchConfig, params: Dict[str, Any],
               batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(cfg, params, batch["frames"].astype(
        params["final_norm"].dtype))
    x = embed_tokens(params["embed"], inputs)
    block = functools.partial(_dec_block_train, cfg, enc_out)
    blk = jax.checkpoint(block) if cfg.remat else block

    def body(carry, p):
        return blk(carry, p), None

    h, _ = jax.lax.scan(body, x, params["decoder"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    mask = (labels >= 0).astype(jnp.float32)
    loss_sum, count = chunked_cross_entropy(
        params["embed"], h, jnp.maximum(labels, 0), mask, cfg.loss_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"ce_loss": loss, "loss": loss, "tokens": count}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_kv: attn.KVCache      # (L, B, S_max, n_kv, h)
    cross_k: jax.Array         # (L, B, T, n_kv, h) — projected encoder output
    cross_v: jax.Array


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> EncDecCache:
    h = cfg.resolved_head_dim()
    cross = (cfg.n_layers, batch, cfg.frontend_len, cfg.n_kv_heads, h)
    return EncDecCache(
        attn.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype),
        jnp.zeros(cross, dtype), jnp.zeros(cross, dtype))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, dtype) -> EncDecCache:
    h = cfg.resolved_head_dim()
    cross = (cfg.n_layers, batch, cfg.frontend_len, cfg.n_kv_heads, h)
    return EncDecCache(
        attn.kv_cache_specs(cfg, batch, max_len, cfg.n_layers, dtype),
        jax.ShapeDtypeStruct(cross, dtype), jax.ShapeDtypeStruct(cross, dtype))


def prefill(cfg: ArchConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], max_len: int
            ) -> Tuple[jax.Array, EncDecCache]:
    """Encode frames + run decoder over the prompt, priming both caches."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    dtype = params["final_norm"].dtype
    enc_out = encode(cfg, params, batch["frames"].astype(dtype))
    x = embed_tokens(params["embed"], tokens)
    hd = cfg.resolved_head_dim()
    T = enc_out.shape[1]

    def body(carry, p):
        x = carry
        xn = rms_norm(x, p["self_norm"], cfg.norm_eps)
        positions = jnp.arange(S)[None, :]
        q, k, v = attn.qkv(cfg, p["self_attn"], xn, positions)
        o = attn.attend(q, k, v, causal=True, softmax_scale=hd ** -0.5)
        h = x + o.reshape(B, S, -1) @ attn.wo_matrix(p["self_attn"])
        h = h + attn.cross_attention_train(
            cfg, p["cross_attn"], rms_norm(h, p["cross_norm"], cfg.norm_eps),
            enc_out)
        out = h + mlp_apply(cfg, p["mlp"],
                            rms_norm(h, p["mlp_norm"], cfg.norm_eps))
        ck = (enc_out @ p["cross_attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
        cv = (enc_out @ p["cross_attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
        pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
        return out, (jnp.pad(k, pad), jnp.pad(v, pad), ck, cv)

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["decoder"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h[:, -1:, :])
    lengths = jnp.full((B,), S, jnp.int32)
    return logits, EncDecCache(attn.KVCache(ks, vs, lengths), cks, cvs)


def decode_step(cfg: ArchConfig, params: Dict[str, Any], cache: EncDecCache,
                tokens: jax.Array) -> Tuple[jax.Array, EncDecCache]:
    kv = cache.self_kv
    x = embed_tokens(params["embed"], tokens)
    hd = cfg.resolved_head_dim()

    def body(carry, xs):
        p, k_c, v_c, ck, cv = xs
        xn = rms_norm(carry, p["self_norm"], cfg.norm_eps)
        o, k_c, v_c = attn.attention_decode(cfg, p["self_attn"], xn,
                                            k_c, v_c, kv.length)
        h = carry + o
        hn = rms_norm(h, p["cross_norm"], cfg.norm_eps)
        B = hn.shape[0]
        q = (hn @ p["cross_attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        co = attn.gqa_attend(q, ck, cv, None, softmax_scale=hd ** -0.5)
        h = h + co.reshape(B, 1, -1) @ p["cross_attn"]["wo"]
        out = h + mlp_apply(cfg, p["mlp"],
                            rms_norm(h, p["mlp_norm"], cfg.norm_eps))
        return out, (k_c, v_c)

    h, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], kv.k, kv.v, cache.cross_k, cache.cross_v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h)
    return logits, EncDecCache(attn.KVCache(ks, vs, kv.length + 1),
                               cache.cross_k, cache.cross_v)
