"""Zamba2-style hybrid: Mamba2 backbone + one shared attention block.

The shared attention+MLP block (a single parameter set) is applied every
``attn_every`` layers via ``lax.cond`` inside the layer scan — which shows up
in the ScalAna PSG as a Branch vertex nested in the layer Loop, exactly the
control structure the paper's backtracking walks through.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.layers import (
    chunked_cross_entropy,
    embed_specs,
    embed_tokens,
    logits_for,
    mlp_apply,
    mlp_specs,
    rms_norm,
)
from repro.models.params import P, Specs
from repro.models.transformer import stack_specs


def n_attn_sites(cfg: ArchConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def hybrid_specs(cfg: ArchConfig) -> Specs:
    mamba_layer = {
        "norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "ssd": mamba2.ssd_block_specs(cfg),
    }
    shared = {
        "attn_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "attn": attn.attention_specs(cfg),
        "mlp_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "mlp": mlp_specs(cfg),
    }
    return {
        "embed": embed_specs(cfg),
        "layers": stack_specs(mamba_layer, cfg.n_layers),
        "shared": shared,
        "final_norm": P((cfg.d_model,), ("embed",), init="zeros"),
    }


def _shared_block_train(cfg: ArchConfig, p: Dict[str, Any],
                        x: jax.Array) -> jax.Array:
    h = x + attn.attention_train(cfg, p["attn"],
                                 rms_norm(x, p["attn_norm"], cfg.norm_eps))
    return h + mlp_apply(cfg, p["mlp"],
                         rms_norm(h, p["mlp_norm"], cfg.norm_eps))


def backbone_train(cfg: ArchConfig, params: Dict[str, Any],
                   x: jax.Array) -> jax.Array:
    shared = params["shared"]

    def block(x, layer_params, idx):
        x = jax.lax.cond(idx % cfg.attn_every == 0,
                         lambda v: _shared_block_train(cfg, shared, v),
                         lambda v: v, x)
        y = mamba2.ssd_block_train(cfg, layer_params["ssd"],
                                   rms_norm(x, layer_params["norm"],
                                            cfg.norm_eps))
        out = x + y
        return logical_constraint(out, "batch", "res_seq", "embed")

    blk = jax.checkpoint(block) if cfg.remat else block

    def body(carry, xs):
        layer_params, idx = xs
        return blk(carry, layer_params, idx), None

    idxs = jnp.arange(cfg.n_layers)
    h, _ = jax.lax.scan(body, x, (params["layers"], idxs))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def train_loss(cfg: ArchConfig, params: Dict[str, Any],
               batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = embed_tokens(params["embed"], inputs)
    h = backbone_train(cfg, params, x)
    mask = (labels >= 0).astype(jnp.float32)
    loss_sum, count = chunked_cross_entropy(
        params["embed"], h, jnp.maximum(labels, 0), mask, cfg.loss_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"ce_loss": loss, "loss": loss, "tokens": count}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class HybridCache(NamedTuple):
    ssm: mamba2.SSMState          # stacked (L, ...)
    k: jax.Array                  # (sites, B, S_max, n_kv, h)
    v: jax.Array
    length: jax.Array             # (B,)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> HybridCache:
    sites = n_attn_sites(cfg)
    h = cfg.resolved_head_dim()
    kv_shape = (sites, batch, max_len, cfg.n_kv_heads, h)
    return HybridCache(
        mamba2.init_ssm_state(cfg, batch, cfg.n_layers, dtype),
        jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype),
        jnp.zeros((batch,), jnp.int32),
    )


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, dtype) -> HybridCache:
    sites = n_attn_sites(cfg)
    h = cfg.resolved_head_dim()
    kv_shape = (sites, batch, max_len, cfg.n_kv_heads, h)
    return HybridCache(
        mamba2.ssm_state_specs(cfg, batch, cfg.n_layers, dtype),
        jax.ShapeDtypeStruct(kv_shape, dtype),
        jax.ShapeDtypeStruct(kv_shape, dtype),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


def _shared_block_decode(cfg: ArchConfig, p: Dict[str, Any], x: jax.Array,
                         k_site: jax.Array, v_site: jax.Array,
                         lengths: jax.Array):
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    o, k_site, v_site = attn.attention_decode(cfg, p["attn"], xn,
                                              k_site, v_site, lengths)
    h = x + o
    h = h + mlp_apply(cfg, p["mlp"], rms_norm(h, p["mlp_norm"], cfg.norm_eps))
    return h, k_site, v_site


def decode_step(cfg: ArchConfig, params: Dict[str, Any], cache: HybridCache,
                tokens: jax.Array) -> Tuple[jax.Array, HybridCache]:
    shared = params["shared"]
    x = embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        x, kc, vc = carry
        layer_params, conv_s, ssm_h, idx = xs
        site = idx // cfg.attn_every

        def with_attn(operand):
            x, kc, vc = operand
            ks = jax.lax.dynamic_index_in_dim(kc, site, 0, keepdims=False)
            vs = jax.lax.dynamic_index_in_dim(vc, site, 0, keepdims=False)
            x, ks, vs = _shared_block_decode(cfg, shared, x, ks, vs,
                                             cache.length)
            kc = jax.lax.dynamic_update_index_in_dim(kc, ks, site, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, vs, site, 0)
            return x, kc, vc

        x, kc, vc = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                 lambda o: o, (x, kc, vc))
        y, (conv_s, ssm_h) = mamba2.ssd_block_decode(
            cfg, layer_params["ssd"],
            rms_norm(x, layer_params["norm"], cfg.norm_eps), (conv_s, ssm_h))
        return (x + y, kc, vc), (conv_s, ssm_h)

    idxs = jnp.arange(cfg.n_layers)
    (h, kc, vc), (conv_s, ssm_h) = jax.lax.scan(
        body, (x, cache.k, cache.v),
        (params["layers"], cache.ssm.conv, cache.ssm.h, idxs))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h)
    new_cache = HybridCache(mamba2.SSMState(conv_s, ssm_h), kc, vc,
                            cache.length + 1)
    return logits, new_cache


def prefill(cfg: ArchConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], max_len: int
            ) -> Tuple[jax.Array, HybridCache]:
    """Chunked prefill: SSD chunk scan per layer + shared-attn KV capture."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    shared = params["shared"]
    sites = n_attn_sites(cfg)
    h = cfg.resolved_head_dim()
    dtype = x.dtype
    kbuf = jnp.zeros((sites, B, max_len, cfg.n_kv_heads, h), dtype)
    vbuf = jnp.zeros_like(kbuf)

    def body(carry, xs):
        x, kbuf, vbuf = carry
        layer_params, idx = xs
        site = idx // cfg.attn_every

        def with_attn(operand):
            x, kbuf, vbuf = operand
            xn = rms_norm(x, shared["attn_norm"], cfg.norm_eps)
            positions = jnp.arange(S)[None, :]
            q, k, v = attn.qkv(cfg, shared["attn"], xn, positions)
            o = attn.attend(q, k, v, causal=True, softmax_scale=h ** -0.5)
            hx = x + o.reshape(B, S, -1) @ attn.wo_matrix(shared["attn"])
            hx = hx + mlp_apply(cfg, shared["mlp"],
                                rms_norm(hx, shared["mlp_norm"], cfg.norm_eps))
            pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
            kbuf = jax.lax.dynamic_update_index_in_dim(
                kbuf, jnp.pad(k, pad), site, 0)
            vbuf = jax.lax.dynamic_update_index_in_dim(
                vbuf, jnp.pad(v, pad), site, 0)
            return hx, kbuf, vbuf

        x, kbuf, vbuf = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                     lambda o: o, (x, kbuf, vbuf))
        y, (conv_s, ssm_h) = mamba2.ssd_block_train(
            cfg, layer_params["ssd"],
            rms_norm(x, layer_params["norm"], cfg.norm_eps),
            return_state=True)
        return (x + y, kbuf, vbuf), (conv_s, ssm_h)

    idxs = jnp.arange(cfg.n_layers)
    (hx, kbuf, vbuf), (conv_s, ssm_h) = jax.lax.scan(
        body, (x, kbuf, vbuf), (params["layers"], idxs))
    hx = rms_norm(hx, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], hx[:, -1:, :])
    lengths = jnp.full((B,), S, jnp.int32)
    cache = HybridCache(mamba2.SSMState(conv_s, ssm_h), kbuf, vbuf, lengths)
    return logits, cache
