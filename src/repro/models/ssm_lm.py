"""Pure-SSM language model (mamba2-130m family): attention-free."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint
from repro.models import mamba2
from repro.models.layers import (
    chunked_cross_entropy,
    embed_specs,
    embed_tokens,
    logits_for,
    rms_norm,
)
from repro.models.params import P, Specs
from repro.models.transformer import stack_specs


def ssm_lm_specs(cfg: ArchConfig) -> Specs:
    layer = {
        "norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "ssd": mamba2.ssd_block_specs(cfg),
    }
    return {
        "embed": embed_specs(cfg),
        "layers": stack_specs(layer, cfg.n_layers),
        "final_norm": P((cfg.d_model,), ("embed",), init="zeros"),
    }


def _backbone(cfg: ArchConfig, params: Dict[str, Any], x: jax.Array,
              collect_state: bool):
    def block(x, layer_params):
        y, st = mamba2.ssd_block_train(
            cfg, layer_params["ssd"],
            rms_norm(x, layer_params["norm"], cfg.norm_eps),
            return_state=True)
        out = logical_constraint(x + y, "batch", "res_seq", "embed")
        return out, st

    blk = jax.checkpoint(block) if (cfg.remat and not collect_state) else block

    def body(carry, layer_params):
        out, st = blk(carry, layer_params)
        return out, st if collect_state else None

    h, states = jax.lax.scan(body, x, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), states


def train_loss(cfg: ArchConfig, params: Dict[str, Any],
               batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = embed_tokens(params["embed"], inputs)
    h, _ = _backbone(cfg, params, x, collect_state=False)
    mask = (labels >= 0).astype(jnp.float32)
    loss_sum, count = chunked_cross_entropy(
        params["embed"], h, jnp.maximum(labels, 0), mask, cfg.loss_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"ce_loss": loss, "loss": loss, "tokens": count}


class SSMCache(NamedTuple):
    ssm: mamba2.SSMState       # stacked (L, ...)
    length: jax.Array          # (B,)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> SSMCache:
    del max_len                # state is O(1) in history length
    return SSMCache(mamba2.init_ssm_state(cfg, batch, cfg.n_layers, dtype),
                    jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, dtype) -> SSMCache:
    del max_len
    return SSMCache(mamba2.ssm_state_specs(cfg, batch, cfg.n_layers, dtype),
                    jax.ShapeDtypeStruct((batch,), jnp.int32))


def prefill(cfg: ArchConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], max_len: int
            ) -> Tuple[jax.Array, SSMCache]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    h, (conv_s, ssm_h) = _backbone(cfg, params, x, collect_state=True)
    logits = logits_for(params["embed"], h[:, -1:, :])
    cache = SSMCache(mamba2.SSMState(conv_s, ssm_h),
                     jnp.full((B,), S, jnp.int32))
    return logits, cache


def decode_step(cfg: ArchConfig, params: Dict[str, Any], cache: SSMCache,
                tokens: jax.Array) -> Tuple[jax.Array, SSMCache]:
    x = embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        layer_params, conv_s, ssm_h = xs
        y, (conv_s, ssm_h) = mamba2.ssd_block_decode(
            cfg, layer_params["ssd"],
            rms_norm(carry, layer_params["norm"], cfg.norm_eps),
            (conv_s, ssm_h))
        return carry + y, (conv_s, ssm_h)

    h, (conv_s, ssm_h) = jax.lax.scan(
        body, x, (params["layers"], cache.ssm.conv, cache.ssm.h))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h)
    return logits, SSMCache(mamba2.SSMState(conv_s, ssm_h), cache.length + 1)
