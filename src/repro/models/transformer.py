"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layers are scanned (params stacked on a leading 'layers' axis) so HLO size
and compile time are depth-independent, and the layer loop appears as a
single Loop vertex in the ScalAna PSG — mirroring the paper's treatment of
outer iteration loops.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (
    chunked_cross_entropy,
    embed_specs,
    embed_tokens,
    logits_for,
    mlp_apply,
    mlp_specs,
    rms_norm,
)
from repro.models.params import P, Specs


def stack_specs(specs: Specs, n: int) -> Specs:
    """Add a leading stacked-layer dim to every leaf."""
    out: Specs = {}
    for k, v in specs.items():
        if isinstance(v, P):
            out[k] = P((n,) + v.shape, ("layers",) + v.axes, v.init, v.scale)
        else:
            out[k] = stack_specs(v, n)
    return out


def block_specs(cfg: ArchConfig) -> Specs:
    specs: Specs = {
        "attn_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        "attn": attn.attention_specs(cfg),
        "mlp_norm": P((cfg.d_model,), ("embed",), init="zeros"),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_lib.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)
    return specs


def lm_specs(cfg: ArchConfig) -> Specs:
    return {
        "embed": embed_specs(cfg),
        "layers": stack_specs(block_specs(cfg), cfg.n_layers),
        "final_norm": P((cfg.d_model,), ("embed",), init="zeros"),
        **({"patch_proj": P((cfg.d_model, cfg.d_model), ("embed", "embed"))}
           if cfg.family == "vlm" else {}),
    }


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------

def _block_train(cfg: ArchConfig, moe_strategy: str, x: jax.Array,
                 p: Dict[str, Any]) -> Tuple[jax.Array, jax.Array]:
    """One transformer block. Returns (x, moe_aux) with aux=(lb, z) or zeros."""
    h = x + attn.attention_train(cfg, p["attn"],
                                 rms_norm(x, p["attn_norm"], cfg.norm_eps))
    hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        # single explicit gather point: under SP the dispatch/combine
        # einsums would otherwise each re-gather the seq-sharded stream
        # (measured 54 AGs/layer on dbrx -- see EXPERIMENTS.md SPerf)
        hn = logical_constraint(hn, "batch", "seq", "embed")
        y, m = moe_lib.moe_apply(cfg, p["moe"], hn, moe_strategy)
        aux = jnp.stack([m["moe_aux_loss"], m["moe_z_loss"],
                         m["moe_drop_frac"]])
    else:
        y = mlp_apply(cfg, p["mlp"], hn)
        aux = jnp.zeros((3,), jnp.float32)
    out = h + y
    out = logical_constraint(out, "batch", "res_seq", "embed")
    return out, aux


def backbone_train(cfg: ArchConfig, params: Dict[str, Any], x: jax.Array,
                   moe_strategy: str = "einsum") -> Tuple[jax.Array, jax.Array]:
    """Run all blocks over embedded input x: (B, S, D). Returns (h, aux)."""
    block = functools.partial(_block_train, cfg, moe_strategy)
    if cfg.remat:
        block = jax.checkpoint(block)

    def body(carry, layer_params):
        y, aux = block(carry, layer_params)
        return y, aux

    h, auxs = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, jnp.sum(auxs, axis=0)


def _embed_inputs(cfg: ArchConfig, params: Dict[str, Any],
                  batch: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    x = embed_tokens(params["embed"], tokens)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    return x


def train_loss(cfg: ArchConfig, params: Dict[str, Any],
               batch: Dict[str, jax.Array], moe_strategy: str = "einsum"
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = _embed_inputs(cfg, params, batch, inputs)
    h, aux = backbone_train(cfg, params, x, moe_strategy)
    if cfg.family == "vlm":                   # loss over text positions only
        h = h[:, batch["patches"].shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    loss_sum, count = chunked_cross_entropy(
        params["embed"], h, jnp.maximum(labels, 0), mask, cfg.loss_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)
    metrics = {"ce_loss": loss, "tokens": count}
    if cfg.family == "moe":
        metrics.update(moe_aux_loss=aux[0], moe_z_loss=aux[1],
                       moe_drop_frac=aux[2])
        loss = loss + 0.01 * aux[0] + 1e-3 * aux[1]
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

class LMCache(NamedTuple):
    kv: attn.KVCache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> LMCache:
    return LMCache(attn.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, dtype) -> LMCache:
    return LMCache(attn.kv_cache_specs(cfg, batch, max_len, cfg.n_layers, dtype))


def _block_prefill(cfg: ArchConfig, x: jax.Array, p: Dict[str, Any],
                   max_len: int):
    """Block forward that also emits this layer's (padded) K/V for the cache."""
    B, S, _ = x.shape
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    positions = jnp.arange(S)[None, :]
    q, k, v = attn.qkv(cfg, p["attn"], xn, positions)
    o = attn.attend(q, k, v, causal=True,
                    softmax_scale=cfg.resolved_head_dim() ** -0.5)
    h = x + o.reshape(B, S, -1) @ attn.wo_matrix(p["attn"])
    hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_lib.moe_apply(
            cfg, p["moe"], hn,
            capacity_factor=moe_lib.SERVE_CAPACITY_FACTOR)
    else:
        y = mlp_apply(cfg, p["mlp"], hn)
    out = h + y
    pad = max_len - S
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, (kp, vp)


def prefill(cfg: ArchConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], max_len: int
            ) -> Tuple[jax.Array, LMCache]:
    """Process the full prompt; returns (last-position logits, primed cache)."""
    tokens = batch["tokens"]
    x = _embed_inputs(cfg, params, batch, tokens)
    B, S, _ = x.shape

    def body(carry, layer_params):
        y, kv = _block_prefill(cfg, carry, layer_params, max_len)
        return y, kv

    h, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h[:, -1:, :])
    lengths = jnp.full((B,), S, jnp.int32)
    return logits, LMCache(attn.KVCache(ks, vs, lengths))


def decode_step(cfg: ArchConfig, params: Dict[str, Any], cache: LMCache,
                tokens: jax.Array) -> Tuple[jax.Array, LMCache]:
    """One greedy decode step. tokens: (B, 1) int32."""
    kv = cache.kv
    x = embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        layer_params, k_c, v_c = xs
        xn = rms_norm(carry, layer_params["attn_norm"], cfg.norm_eps)
        o, k_c, v_c = attn.attention_decode(cfg, layer_params["attn"], xn,
                                            k_c, v_c, kv.length)
        h = carry + o
        hn = rms_norm(h, layer_params["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_lib.moe_apply(
                cfg, layer_params["moe"], hn,
                capacity_factor=moe_lib.SERVE_CAPACITY_FACTOR)
        else:
            y = mlp_apply(cfg, layer_params["mlp"], hn)
        return h + y, (k_c, v_c)

    h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], kv.k, kv.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_for(params["embed"], h)
    new_cache = LMCache(attn.KVCache(ks, vs, kv.length + 1))
    return logits, new_cache
