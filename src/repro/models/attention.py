"""GQA/MQA/MHA attention: training (causal), prefill, and cached decode.

The einsum formulation below is the XLA path used for lowering/dry-run; the
Pallas flash-attention kernel (repro.kernels.flash_attention) is an optional
drop-in for the training path on real TPUs (cfg-level switch in the bundle).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint, weight_constraint
from repro.models.layers import apply_rotary, rotary_embedding
from repro.models.params import P


def wo_matrix(p: Dict[str, jax.Array]) -> jax.Array:
    """Output projection with FSDP gather-at-use applied."""
    return weight_constraint(p["wo"], "q_features", "embed")

NEG_INF = -1e30


def attention_specs(cfg: ArchConfig) -> Dict[str, P]:
    d, h = cfg.d_model, cfg.resolved_head_dim()
    return {
        "wq": P((d, cfg.n_heads * h), ("embed", "q_features")),
        "wk": P((d, cfg.n_kv_heads * h), ("embed", "kv_features")),
        "wv": P((d, cfg.n_kv_heads * h), ("embed", "kv_features")),
        "wo": P((cfg.n_heads * h, d), ("q_features", "embed")),
    }


def qkv(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
        positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> q (B,S,nq,h), k/v (B,S,nkv,h), rotary applied."""
    B, S, _ = x.shape
    h = cfg.resolved_head_dim()
    wq = weight_constraint(p["wq"], "embed", "q_features")
    wk = weight_constraint(p["wk"], "embed", "kv_features")
    wv = weight_constraint(p["wv"], "embed", "kv_features")
    q = (x @ wq).reshape(B, S, cfg.n_heads, h)
    k = (x @ wk).reshape(B, S, cfg.n_kv_heads, h)
    v = (x @ wv).reshape(B, S, cfg.n_kv_heads, h)
    cos, sin = rotary_embedding(positions, h, cfg.rope_theta, x.dtype)
    q = apply_rotary(q, cos, sin)
    k = apply_rotary(k, cos, sin)
    q = logical_constraint(q, "batch", "seq", "heads", None)
    k = logical_constraint(k, "batch", "kv_seq", "kv_heads", None)
    v = logical_constraint(v, "batch", "kv_seq", "kv_heads", None)
    return q, k, v


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: Optional[jax.Array], *, softmax_scale: float) -> jax.Array:
    """Grouped-query attention core.

    q: (B, Sq, nq, h);  k, v: (B, Sk, nkv, h);  mask: broadcastable to
    (B, nkv, g, Sq, Sk) or None.  Returns (B, Sq, nq, h).
    """
    B, Sq, nq, h = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(B, Sq, nkv, g, h)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * softmax_scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, nq, h)


def causal_mask(Sq: int, Sk: int, offset: int = 0) -> jax.Array:
    """(1, 1, 1, Sq, Sk) causal mask; offset = #cached tokens before q."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    return (kpos <= qpos)[None, None, None]


CHUNKED_ATTN_THRESHOLD = 2048     # switch to O(S·BQ) attention above this


def chunked_gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool, softmax_scale: float,
                       q_chunk: int = 512) -> jax.Array:
    """Memory-efficient attention: lax.scan over query blocks.

    The plain einsum path materializes (B, nkv, g, Sq, Sk) scores —
    quadratic; at 32 k context that is PBs.  Scanning query blocks keeps
    only (B, nkv, g, BQ, Sk) live (the XLA analogue of flash attention's
    outer loop; the Pallas kernel additionally blocks the k axis in VMEM).

    Numerics match gqa_attend exactly (f32 softmax over the full key
    axis).  §Perf iterations 4/5/5b tried q_chunk=1024, bf16
    probabilities, and hand-staged softmax (pre-scaled q, post-PV
    normalization) — all REFUTED on the lowered-IR byte accounting:
    XLA's recognized softmax pattern fuses better than hand staging, and
    bf16 probabilities just add converts under CPU legalization.  The
    reduced-precision-probability trade lives where it belongs, in the
    Pallas flash kernel (repro.kernels.flash_attention).
    """
    B, Sq, nq, h = q.shape
    nkv, Sk = k.shape[2], k.shape[1]
    g = nq // nkv
    BQ = min(q_chunk, Sq)
    while Sq % BQ:
        BQ -= 1
    nQ = Sq // BQ
    qg = q.reshape(B, nQ, BQ, nkv, g, h)
    kf, vf = k, v

    def chunk(qi, blk):                               # blk: (B,BQ,nkv,g,h)
        scores = jnp.einsum("bskgh,btkh->bkgst", blk, kf,
                            preferred_element_type=jnp.float32) * softmax_scale
        if causal:
            rows = qi * BQ + jnp.arange(BQ)[:, None]
            cols = jnp.arange(Sk)[None, :]
            scores = jnp.where((cols <= rows)[None, None, None],
                               scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgst,btkh->bskgh", w, vf)

    # inner remat: without it the scan's backward saves softmax(scores) for
    # every chunk — re-materializing the full quadratic matrix it exists to
    # avoid.  Recomputing scores per chunk in backward is the flash-
    # attention trade (+1 matmul) and keeps peak memory O(S·BQ).
    chunk = jax.checkpoint(chunk,
                           policy=jax.checkpoint_policies.nothing_saveable)

    def body(_, args):
        qi, blk = args
        return None, chunk(qi, blk)

    _, outs = jax.lax.scan(body, None,
                           (jnp.arange(nQ), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1)                    # (B,nQ,BQ,nkv,g,h)
    return out.reshape(B, Sq, nq, h)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
           softmax_scale: float) -> jax.Array:
    """Quadratic einsum path below the threshold, chunked scan above."""
    Sq, Sk = q.shape[1], k.shape[1]
    if max(Sq, Sk) > CHUNKED_ATTN_THRESHOLD:
        return chunked_gqa_attend(q, k, v, causal=causal,
                                  softmax_scale=softmax_scale)
    mask = causal_mask(Sq, Sk) if causal else None
    return gqa_attend(q, k, v, mask, softmax_scale=softmax_scale)


def attention_train(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                    *, causal: bool = True) -> jax.Array:
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = qkv(cfg, p, x, positions)
    scale = cfg.resolved_head_dim() ** -0.5
    if cfg.use_kernels:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, softmax_scale=scale)
    else:
        out = attend(q, k, v, causal=causal, softmax_scale=scale)
    out = logical_constraint(out, "batch", "seq", "heads", None)
    return out.reshape(B, S, -1) @ wo_matrix(p)


def cross_attention_train(cfg: ArchConfig, p: Dict[str, jax.Array],
                          x: jax.Array, kv_src: jax.Array) -> jax.Array:
    """Decoder cross-attention over encoder output (no rotary, no mask)."""
    B, S, _ = x.shape
    T = kv_src.shape[1]
    h = cfg.resolved_head_dim()
    wq = weight_constraint(p["wq"], "embed", "q_features")
    wk = weight_constraint(p["wk"], "embed", "kv_features")
    wv = weight_constraint(p["wv"], "embed", "kv_features")
    q = (x @ wq).reshape(B, S, cfg.n_heads, h)
    k = (kv_src @ wk).reshape(B, T, cfg.n_kv_heads, h)
    v = (kv_src @ wv).reshape(B, T, cfg.n_kv_heads, h)
    out = gqa_attend(q, k, v, None, softmax_scale=h ** -0.5)
    return out.reshape(B, S, -1) @ wo_matrix(p)


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, n_kv, h)
    v: jax.Array          # (B, S_max, n_kv, h)
    length: jax.Array     # (B,) int32 — tokens already cached


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, n_layers: int,
                  dtype) -> KVCache:
    h = cfg.resolved_head_dim()
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, h)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def kv_cache_specs(cfg: ArchConfig, batch: int, max_len: int, n_layers: int,
                   dtype) -> KVCache:
    """Abstract cache (dry-run serve_step input)."""
    h = cfg.resolved_head_dim()
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, h)
    return KVCache(jax.ShapeDtypeStruct(shape, dtype),
                   jax.ShapeDtypeStruct(shape, dtype),
                   jax.ShapeDtypeStruct((batch,), jnp.int32))


def attention_decode(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); caches (B, S_max, n_kv, h).

    Returns (out (B,1,D), new_k_cache, new_v_cache).
    """
    B, one, _ = x.shape
    S_max = k_cache.shape[1]
    positions = lengths[:, None]                                    # (B, 1)
    q, k, v = qkv(cfg, p, x, positions)
    # scatter the new kv at position `lengths` per batch row
    onehot = jax.nn.one_hot(lengths, S_max, dtype=k.dtype)          # (B, S_max)
    k_cache = k_cache * (1 - onehot)[..., None, None] + onehot[..., None, None] * k
    v_cache = v_cache * (1 - onehot)[..., None, None] + onehot[..., None, None] * v
    k_cache = logical_constraint(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = logical_constraint(v_cache, "batch", "kv_seq", "kv_heads", None)
    valid = (jnp.arange(S_max)[None, :] <= lengths[:, None])        # (B, S_max)
    mask = valid[:, None, None, None, :]                            # b k g s t
    out = gqa_attend(q, k_cache, v_cache, mask,
                     softmax_scale=cfg.resolved_head_dim() ** -0.5)
    out = out.reshape(B, one, -1) @ wo_matrix(p)
    return out, k_cache, v_cache
