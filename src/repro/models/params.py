"""Declarative parameter specs.

Each model declares its parameters once as a (possibly nested) dict of
``P(shape, logical_axes, init)``; from that single source of truth we derive
initialization, sharding (PartitionSpecs via logical rules), abstract
ShapeDtypeStructs for the dry-run, and parameter counts — guaranteed
consistent with each other.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.axes import spec_for


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis names, len == ndim
    init: str = "normal"                   # normal | zeros | ones | embed
    scale: Optional[float] = None          # overrides fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Specs = Dict[str, Any]   # nested dict: str -> P | Specs


def _fan_in(spec: P) -> int:
    # convention: last dim is the output dim; everything else is fan-in,
    # except stacked-layer / expert axes which don't contract in the matmul.
    dims = [d for d, a in zip(spec.shape, spec.axes) if a not in ("layers", "experts")]
    if len(dims) <= 1:
        return max(dims[0] if dims else 1, 1)
    return max(int(np.prod(dims[:-1])), 1)


def _init_leaf(key: jax.Array, spec: P, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        scale = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(_fan_in(spec))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def _walk(specs: Specs, path=()):
    for k, v in sorted(specs.items()):
        if isinstance(v, P):
            yield path + (k,), v
        else:
            yield from _walk(v, path + (k,))


def init_params(specs: Specs, key: jax.Array, dtype) -> Dict[str, Any]:
    leaves = list(_walk(specs))
    keys = jax.random.split(key, max(len(leaves), 1))
    out: Dict[str, Any] = {}
    for (path, spec), k in zip(leaves, keys):
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = _init_leaf(k, spec, dtype)
    return out


def abstract_params(specs: Specs, dtype) -> Dict[str, Any]:
    """ShapeDtypeStructs matching init_params (used by the dry-run)."""
    out: Dict[str, Any] = {}
    for path, spec in _walk(specs):
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = jax.ShapeDtypeStruct(spec.shape, dtype)
    return out


def param_specs_tree(specs: Specs) -> Dict[str, Any]:
    """PartitionSpec pytree (resolved against the active mesh/rules)."""
    out: Dict[str, Any] = {}
    for path, spec in _walk(specs):
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = spec_for(spec.axes, spec.shape)
    return out


def param_count(specs: Specs) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(specs))


def param_bytes(specs: Specs, dtype) -> int:
    return param_count(specs) * jnp.dtype(dtype).itemsize
