"""Mamba2 / SSD (state-space duality) block — chunked scan + one-step decode.

Selective state space:  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t,
y_t = C_t . h_t + D * x_t, with per-head scalar A (Mamba2's SSD restriction).

Training uses the chunked SSD algorithm (arXiv:2405.21060 §6): intra-chunk
"attention-like" term + inter-chunk state recurrence via associative scan —
sub-quadratic in sequence length and SP-friendly.  Decode carries
(conv_state, ssm_state) and is O(1) per token regardless of history length,
which is why the long_500k shape is assigned to the SSM/hybrid families.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint, weight_constraint
from repro.models.layers import rms_norm
from repro.models.params import P


def ssd_block_specs(cfg: ArchConfig) -> Dict[str, P]:
    """Split, layout-native projections (§Perf mamba2 iteration 1).

    A packed in_proj (d, 2di+2N+H) sharded on 'model' forced GSPMD to
    halo-exchange every shard-misaligned slice (z/x/B/C/dt split, head
    reshape): 1128 collective-permutes + 24 AGs per prefill on the 16x16
    mesh.  Separate per-stream weights — with the x streams as 3-D
    (d, H, P) tensors — produce every activation directly in its sharded
    layout: no slicing or reshaping of sharded dims at all."""
    d, n, hh, pd = cfg.d_model, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cw = cfg.conv_width
    return {
        "w_z": P((d, hh, pd), ("embed", "ssm_heads", "ssm_pdim")),
        "w_x": P((d, hh, pd), ("embed", "ssm_heads", "ssm_pdim")),
        "w_B": P((d, n), ("embed", "state")),
        "w_C": P((d, n), ("embed", "state")),
        "w_dt": P((d, hh), ("embed", "ssm_heads")),
        "conv_x_w": P((cw, hh, pd), ("conv", "ssm_heads", "ssm_pdim"),
                      scale=0.5),
        "conv_x_b": P((hh, pd), ("ssm_heads", "ssm_pdim"), init="zeros"),
        "conv_B_w": P((cw, n), ("conv", "state"), scale=0.5),
        "conv_B_b": P((n,), ("state",), init="zeros"),
        "conv_C_w": P((cw, n), ("conv", "state"), scale=0.5),
        "conv_C_b": P((n,), ("state",), init="zeros"),
        "dt_bias": P((hh,), ("ssm_heads",), init="zeros"),
        "A_log": P((hh,), ("ssm_heads",), init="zeros"),
        "D": P((hh,), ("ssm_heads",), init="ones"),
        "gate_norm": P((hh, pd), ("ssm_heads", "ssm_pdim"), init="zeros"),
        "out_proj": P((hh, pd, d), ("ssm_heads", "ssm_pdim", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,...C), w: (W,...C)."""
    W = w.shape[0]
    S = x.shape[1]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0)) + ((0, 0),) * (x.ndim - 2))
    out = jnp.zeros_like(x)
    for i in range(W):                      # W is tiny (4): unrolled taps
        out = out + pad[:, i:i + S] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int, return_final: bool = False):
    """Chunked SSD core (the pure-jnp oracle for the Pallas kernel).

    x: (B,S,H,P)  dt: (B,S,H) (already softplus'ed)  A: (H,) negative
    Bm, Cm: (B,S,N) (single group, broadcast over heads)
    Returns y: (B,S,H,P).
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S0 = S
    if S % Q:                       # pad with dt=0 steps: state-neutral
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A                                             # (B,nc,Q,H)
    s = jnp.cumsum(dA, axis=2)                               # inclusive cumsum
    # intra-chunk: Y[i] = sum_{j<=i} exp(s_i - s_j) dt_j (C_i.B_j) x_j
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)               # (B,nc,Q,Q)
    L = s[:, :, :, None, :] - s[:, :, None, :, :]            # s_i - s_j (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(L), 0.0)
    M = CB[..., None] * L * dtc[:, :, None, :, :]            # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xc)

    # chunk states: St_c = sum_j exp(s_Q - s_j) dt_j B_j (x) x_j  -> (B,nc,H,N,P)
    decay_to_end = jnp.exp(s[:, :, -1:, :] - s)              # (B,nc,Q,H)
    st = jnp.einsum("bcqh,bcqn,bcqhp->bchnp",
                    decay_to_end * dtc, Bc, xc)

    # inter-chunk recurrence over nc: h_c = a_c h_{c-1} + st_c
    a = jnp.exp(s[:, :, -1, :])                              # (B,nc,H)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2[..., None, None] + b2

    a_sc, h_sc = jax.lax.associative_scan(combine, (a, st), axis=1)
    # state entering chunk c = h_{c-1} (zeros for c=0)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_sc[:, :1]), h_sc[:, :-1]], axis=1)  # (B,nc,H,N,P)

    # inter-chunk output: Y[i] += C_i . (exp(s_i) h_prev)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, jnp.exp(s), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)[:, :S0]
    if return_final:
        return y, h_sc[:, -1]                                # (B,H,N,P)
    return y


class SSMState(NamedTuple):
    conv: jax.Array    # (B, conv_width-1, d_inner + 2N) rolling conv input
    h: jax.Array       # (B, H, N, P) ssm state


def init_ssm_state(cfg: ArchConfig, batch: int, n_layers: int, dtype) -> SSMState:
    di, n = cfg.d_inner, cfg.ssm_state
    return SSMState(
        jnp.zeros((n_layers, batch, cfg.conv_width - 1, di + 2 * n), dtype),
        jnp.zeros((n_layers, batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                  jnp.float32),
    )


def ssm_state_specs(cfg: ArchConfig, batch: int, n_layers: int, dtype) -> SSMState:
    di, n = cfg.d_inner, cfg.ssm_state
    return SSMState(
        jax.ShapeDtypeStruct((n_layers, batch, cfg.conv_width - 1, di + 2 * n),
                             dtype),
        jax.ShapeDtypeStruct((n_layers, batch, cfg.ssm_heads, n,
                              cfg.ssm_head_dim), jnp.float32),
    )


def _rms_norm_hp(y: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMS norm over the flattened (H, P) feature dims. y: (B,S,H,P)."""
    dt = y.dtype
    y32 = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(y32), axis=(-2, -1), keepdims=True)
    y32 = y32 * jax.lax.rsqrt(ms + eps)
    return (y32 * (1.0 + w.astype(jnp.float32))).astype(dt)


def ssd_block_train(cfg: ArchConfig, p: Dict[str, jax.Array],
                    x: jax.Array, return_state: bool = False):
    """Full Mamba2 block, training/prefill path. x: (B,S,D) -> (B,S,D).

    With ``return_state`` also returns (conv_state, ssm_state) at sequence
    end so prefill can hand off to O(1) decode.  All streams are computed
    in their final sharded layout (see ssd_block_specs).
    """
    B, S, _ = x.shape
    di, n, hh, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w_z = weight_constraint(p["w_z"], "embed", "ssm_heads", "ssm_pdim")
    w_x = weight_constraint(p["w_x"], "embed", "ssm_heads", "ssm_pdim")
    z = jnp.einsum("bsd,dhp->bshp", x, w_z)
    x_raw = jnp.einsum("bsd,dhp->bshp", x, w_x)              # (B,S,H,P)
    B_raw = x @ weight_constraint(p["w_B"], "embed", "state")
    C_raw = x @ weight_constraint(p["w_C"], "embed", "state")
    dt = x @ weight_constraint(p["w_dt"], "embed", "ssm_heads")
    x_raw = logical_constraint(x_raw, "batch", "seq", "ssm_heads",
                               "ssm_pdim")
    xh = _causal_conv(x_raw, p["conv_x_w"], p["conv_x_b"])
    Bm = _causal_conv(B_raw, p["conv_B_w"], p["conv_B_b"])
    Cm = _causal_conv(C_raw, p["conv_C_w"], p["conv_C_b"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if cfg.use_kernels:
        from repro.kernels.ssd_scan.ops import ssd_scan
        y, h_final = ssd_scan(xh.astype(jnp.float32), dt, A,
                              Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                              chunk=cfg.ssm_chunk, return_final=True)
    else:
        y, h_final = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                 Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                 cfg.ssm_chunk, return_final=True)
    y = y + p["D"][None, None, :, None].astype(jnp.float32) \
        * xh.astype(jnp.float32)
    y = y.astype(x.dtype)
    y = _rms_norm_hp(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    w_out = weight_constraint(p["out_proj"], "ssm_heads", "ssm_pdim", "embed")
    out = jnp.einsum("bshp,hpd->bsd", y, w_out)
    if return_state:
        W = cfg.conv_width
        # decode conv state stays packed [x | B | C] for a stable cache
        # layout (splitting it at decode touches only (B, W-1, C) scraps)
        conv_state = jnp.concatenate(
            [x_raw[:, S - (W - 1):].reshape(B, W - 1, di),
             B_raw[:, S - (W - 1):], C_raw[:, S - (W - 1):]], axis=-1)
        return out, (conv_state, h_final)
    return out


def ssd_block_decode(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                     state: Tuple[jax.Array, jax.Array]
                     ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode. x: (B,1,D); state = (conv (B,W-1,C), h (B,H,N,P)).

    The packed conv state keeps the cache layout stable; the split here
    touches only (B, W-1, C)-sized scraps (negligible at decode)."""
    conv_state, h = state
    B = x.shape[0]
    di, n, hh, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x0 = x[:, 0]
    z = jnp.einsum("bd,dhp->bhp", x0, p["w_z"])
    x_new = jnp.einsum("bd,dhp->bhp", x0, p["w_x"]).reshape(B, di)
    B_new = x0 @ p["w_B"]
    C_new = x0 @ p["w_C"]
    dt = x0 @ p["w_dt"]
    packed_new = jnp.concatenate([x_new, B_new, C_new], axis=-1)
    # rolling conv window: state holds previous W-1 packed inputs
    window = jnp.concatenate([conv_state, packed_new[:, None, :]],
                             axis=1)                          # (B,W,C)
    new_conv_state = window[:, 1:]
    xw = window[..., :di].reshape(B, -1, hh, pd)              # (B,W,H,P)
    conv_x = jnp.einsum("bwhp,whp->bhp", xw, p["conv_x_w"]) + p["conv_x_b"]
    conv_B = jnp.einsum("bwn,wn->bn", window[..., di:di + n],
                        p["conv_B_w"]) + p["conv_B_b"]
    conv_C = jnp.einsum("bwn,wn->bn", window[..., di + n:],
                        p["conv_C_w"]) + p["conv_C_b"]
    xh = jax.nn.silu(conv_x).astype(jnp.float32)              # (B,H,P)
    Bm = jax.nn.silu(conv_B).astype(jnp.float32)
    Cm = jax.nn.silu(conv_C).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                             # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm, xh)
    h = h * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm, h) + p["D"][None, :, None] * xh
    y = y.astype(x.dtype)
    y = _rms_norm_hp((y * jax.nn.silu(z))[:, None], p["gate_norm"],
                     cfg.norm_eps)[:, 0]
    out = jnp.einsum("bhp,hpd->bd", y, p["out_proj"])
    return out[:, None, :], (new_conv_state, h)
