"""Top-k Mixture-of-Experts FFN with expert-parallel sharding.

Two dispatch strategies, selectable per run (both EP-shardable over the
'experts'->'model' mesh axis):

* ``einsum``  — classic mesh-tensorflow dispatch/combine one-hot einsums
  (baseline; adds a dispatch matmul of ~T*E*C*D FLOPs).
* ``sort``    — sort-by-expert gather/scatter dispatch (beyond-baseline
  optimization; pure data movement, no dispatch matmul).

Capacity-based token dropping (capacity_factor), switch-style load-balance
auxiliary loss and router z-loss are implemented for both.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.axes import logical_constraint, weight_constraint
from repro.models.params import P

CAPACITY_FACTOR = 1.25


def moe_specs(cfg: ArchConfig) -> Dict[str, P]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs = {
        "router": P((d, e), ("embed", "experts")),
        "w_up": P((e, d, f), ("experts", "embed", "mlp")),
        "w_down": P((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        specs["w_gate"] = P((e, d, f), ("experts", "embed", "mlp"))
    return specs


def _expert_weights(cfg: ArchConfig, p: Dict[str, jax.Array]):
    """Expert weights with FSDP gather-at-use on the embed dim (EP kept)."""
    w = {"w_up": weight_constraint(p["w_up"], "experts", "embed", "mlp"),
         "w_down": weight_constraint(p["w_down"], "experts", "mlp", "embed")}
    if "w_gate" in p:
        w["w_gate"] = weight_constraint(p["w_gate"], "experts", "embed", "mlp")
    return w


def _expert_ffn(cfg: ArchConfig, p: Dict[str, jax.Array],
                x: jax.Array) -> jax.Array:
    """x: (E, C, D) -> (E, C, D), per-expert weights stacked on E."""
    p = _expert_weights(cfg, p)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"]),
                        approximate=True) \
            * jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_up"]),
                        approximate=True)
    h = logical_constraint(h, "experts", None, "mlp")
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _routing(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array):
    """Router probabilities and top-k selection.

    x: (T, D). Returns (weights (T,k), experts (T,k), aux_loss, z_loss).
    """
    router = weight_constraint(p["router"], "embed", "experts")
    logits = (x @ router).astype(jnp.float32)                # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # switch-style load-balance loss
    T, E = logits.shape
    me = jnp.mean(probs, axis=0)                             # mean prob / expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0
    ) / cfg.experts_per_token                                # frac tokens / expert
    aux = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return top_w, top_e, aux, z


def _capacity(cfg: ArchConfig, n_tokens: int,
              capacity_factor: float = CAPACITY_FACTOR) -> int:
    """Tokens-per-expert buffer size.

    Clamped to n_tokens (an expert can receive at most every token once), so
    small serve-time batches with a generous factor become exactly dropless.
    """
    cap = int(n_tokens * cfg.experts_per_token * capacity_factor
              // cfg.n_experts)
    return min(max(cap, 4), n_tokens)


def _group_tokens(cfg: ArchConfig, x: jax.Array) -> Tuple[jax.Array, int]:
    """(B,S,D) -> (B, G, gs, D): routing groups.

    The dispatch one-hot is (gs, E, C) with C ∝ gs, i.e. *quadratic* in the
    group size — grouping is what keeps it off the memory roofline (gs=1024,
    E=64, k=6: 16 MB/group bf16 vs. petabytes ungrouped).  Groups split the
    seq dim so the batch dim's ('pod','data') sharding is untouched.
    """
    B, S, D = x.shape
    gs = cfg.moe_group_size or S
    gs = min(gs, S)
    while S % gs:                      # S is 2^k in all assigned shapes
        gs -= 1
    return x.reshape(B, S // gs, gs, D), gs


def moe_apply_einsum(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                     capacity_factor: float = CAPACITY_FACTOR
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Grouped dispatch/combine einsum formulation. x: (B,S,D)."""
    B, S, D = x.shape
    xg, gs = _group_tokens(cfg, x)                           # (B,G,gs,D)
    G = xg.shape[1]
    xf = xg.reshape(B * G * gs, D)
    top_w, top_e, aux, z = _routing(cfg, p, xf)
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(cfg, gs, capacity_factor)
    top_w = top_w.reshape(B, G, gs, k)
    top_e = top_e.reshape(B, G, gs, k)

    # position of each (token, slot) within its expert's per-group buffer
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)       # (B,G,gs,k,E)
    flat = onehot.reshape(B, G, gs * k, E)
    pos = jnp.cumsum(flat, axis=2) - flat                    # (B,G,gs*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(B, G, gs, k)
    keep = pos < C
    w = top_w * keep.astype(top_w.dtype)

    # dispatch (gs,E,C) one-hot and combine weights, per group
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                            dtype=x.dtype)[..., :C]          # (B,G,gs,k,C)
    disp = jnp.einsum("bgske,bgskc->bgsec",
                      onehot.astype(x.dtype), pos_oh)        # (B,G,gs,E,C)
    disp = logical_constraint(disp, "batch", None, None, "experts", None)
    comb = jnp.einsum("bgske,bgskc,bgsk->bgsec",
                      onehot.astype(jnp.float32), pos_oh.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
    comb = logical_constraint(comb, "batch", None, None, "experts", None)

    xe = jnp.einsum("bgsec,bgsd->bgecd", disp, xg)           # (B,G,E,C,D)
    xe = logical_constraint(xe, "batch", None, "experts", None, "embed")
    ye = _expert_ffn_grouped(cfg, p, xe)
    y = jnp.einsum("bgsec,bgecd->bgsd", comb, ye)
    y = y.reshape(B, S, D)
    keepf = jnp.mean(keep.astype(jnp.float32))
    metrics = {"moe_aux_loss": aux, "moe_z_loss": z,
               "moe_drop_frac": 1.0 - keepf}
    return y, metrics


def _expert_ffn_grouped(cfg: ArchConfig, p: Dict[str, jax.Array],
                        xe: jax.Array) -> jax.Array:
    """xe: (B,G,E,C,D) -> (B,G,E,C,D); expert weights stacked on E."""
    p = _expert_weights(cfg, p)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("bgecd,edf->bgecf", xe, p["w_gate"])) \
            * jnp.einsum("bgecd,edf->bgecf", xe, p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(jnp.einsum("bgecd,edf->bgecf", xe, p["w_gate"]),
                        approximate=True) \
            * jnp.einsum("bgecd,edf->bgecf", xe, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bgecd,edf->bgecf", xe, p["w_up"]),
                        approximate=True)
    h = logical_constraint(h, "batch", None, "experts", None, "mlp")
    return jnp.einsum("bgecf,efd->bgecd", h, p["w_down"])


def moe_apply_sort(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
                   capacity_factor: float = CAPACITY_FACTOR
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Grouped sort-based gather/scatter dispatch (no dispatch matmul)."""
    B, S, D = x.shape
    xg, gs = _group_tokens(cfg, x)                           # (B,G,gs,D)
    G = xg.shape[1]
    xf = xg.reshape(B * G * gs, D)
    top_w, top_e, aux, z = _routing(cfg, p, xf)
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(cfg, gs, capacity_factor)

    def one_group(xq, w_q, e_q):
        """xq: (gs,D); w_q, e_q: (gs,k) -> (gs,D) f32, keep frac."""
        flat_e = e_q.reshape(gs * k)
        order = jnp.argsort(flat_e, stable=True)             # slots by expert
        sorted_e = flat_e[order]
        counts = jnp.bincount(sorted_e, length=E)
        starts = jnp.cumsum(counts) - counts                 # (E,)
        pos_in_e = jnp.arange(gs * k) - starts[sorted_e]
        keep = pos_in_e < C
        dest = sorted_e * C + jnp.where(keep, pos_in_e, C)   # C -> overflow
        token_of_slot = order // k
        gathered = xq[token_of_slot]                         # (gs*k, D)
        buf = jnp.zeros((E * C + 1, D), xq.dtype).at[dest].set(gathered)
        xe = buf[:E * C].reshape(E, C, D)
        ye = _expert_ffn(cfg, p, xe).reshape(E * C, D)
        ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], 0)
        back = ye[dest]                                      # (gs*k, D)
        w_sorted = w_q.reshape(gs * k)[order] * keep.astype(w_q.dtype)
        contrib = back * w_sorted[:, None].astype(back.dtype)
        y = jnp.zeros((gs, D), jnp.float32).at[token_of_slot].add(
            contrib.astype(jnp.float32))
        return y, jnp.mean(keep.astype(jnp.float32))

    w_g = top_w.reshape(B, G, gs, k)
    e_g = top_e.reshape(B, G, gs, k)
    y, keepf = jax.vmap(jax.vmap(one_group))(xg, w_g, e_g)
    metrics = {"moe_aux_loss": aux, "moe_z_loss": z,
               "moe_drop_frac": 1.0 - jnp.mean(keepf)}
    return y.astype(x.dtype).reshape(B, S, D), metrics


SERVE_CAPACITY_FACTOR = 2.0     # serve-time: generous, dropless at small T


def moe_apply(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array,
              strategy: str = "einsum",
              capacity_factor: float = CAPACITY_FACTOR):
    if strategy == "sort":
        return moe_apply_sort(cfg, p, x, capacity_factor)
    return moe_apply_einsum(cfg, p, x, capacity_factor)
