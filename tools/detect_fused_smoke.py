"""Interpret-mode smoke for the fused detection kernels
(`make detect-fused-smoke`, wired into `make check`).

Runs the Pallas kernels in interpret mode (the CPU CI path — the same
kernel code that compiles on TPU) on a small randomized case and checks
them against the pure-numpy oracle (`repro.kernels.detect_fused.ref`):

* `fused_non_scalable` — merged stack / slope / share to 1e-12, flag
  set exact;
* `fused_non_scalable_live` — live blocks + historical columns, same
  bars;
* `fused_abnormal` — winner order, scores, count and typical EXACT,
  full-fleet and degraded (padded live-mask) variants.

Exits 0 with a "skipped" note when jax is absent (the no-jax CI job
runs `make check` too); any parity violation exits 1 with the failing
op named.
"""
from __future__ import annotations

import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np


def main() -> int:
    try:
        import jax  # noqa: F401
    except ImportError:
        print("detect-fused smoke: jax not installed — skipped")
        return 0
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.detect_fused import ops, ref

    rng = np.random.default_rng(0)
    S, P, V, k = 3, 37, 11, 9
    t = rng.uniform(0, 2, (S, P, V))
    t[t < 0.3] = 0.0
    var = rng.uniform(0, 0.1, (S, P, V))
    present = rng.random((S, V)) > 0.1
    scales = [9, 18, 37]
    top = np.array([2, 7, 3], np.int32)
    kw = dict(ideal_slope=0.0, slope_margin=0.05, min_share=0.01)
    failures = 0

    def check(name, ok):
        nonlocal failures
        print(f"{'ok  ' if ok else 'FAIL'} {name} (interpret)")
        failures += not ok

    with enable_x64():
        logp = jnp.asarray(np.log(np.asarray(scales, np.float64)))
        tj, vj = jnp.asarray(t), jnp.asarray(var)
        pj, topj = jnp.asarray(present), jnp.asarray(top)

        Mr, slr, _, flr = ref.non_scalable_ref(scales, t, var, present,
                                               top=top, **kw)
        M, sl, _, fl = ops.fused_non_scalable(tj, vj, logp, pj,
                                              top_idx=topj,
                                              interpret=True, **kw)
        check("fused_non_scalable",
              np.abs(np.asarray(M) - Mr).max() < 1e-12
              and np.abs(np.asarray(sl) - slr).max() < 1e-12
              and np.array_equal(np.asarray(fl), flr))

        cuts = [12, 24]
        hist = jnp.asarray(ref.merge_all_ref(t[:-1], var[:-1]))
        M, sl, _, fl = ops.fused_non_scalable_live(
            [jnp.asarray(b) for b in np.split(t[-1], cuts, axis=0)],
            [jnp.asarray(b) for b in np.split(var[-1], cuts, axis=0)],
            hist, logp, pj, topj, interpret=True, **kw)
        check("fused_non_scalable_live",
              np.abs(np.asarray(M) - Mr).max() < 1e-12
              and np.array_equal(np.asarray(fl), flr))

        orr, svr, cr, tyr = ref.abnormal_ref(t[-1], top, 1.5, 0.001, k)
        o, sv, c, ty = ops.fused_abnormal(
            [jnp.asarray(b) for b in np.split(t[-1], cuts, axis=0)],
            topj, 1.5, 0.001, k, interpret=True)
        check("fused_abnormal",
              np.array_equal(np.asarray(o), orr) and int(c) == cr
              and np.array_equal(np.asarray(sv), svr)
              and np.array_equal(np.asarray(ty), tyr))

        live = np.sort(rng.choice(P, size=P - 9, replace=False))
        lpad = np.zeros(P, np.int32)
        lpad[:live.size] = live
        vmask = np.zeros(P, bool)
        vmask[:live.size] = True
        orr, _, cr, tyr = ref.abnormal_ref(t[-1][lpad], top, 1.5, 0.001,
                                           k, valid=vmask)
        o, _, c, ty = ops.fused_abnormal(
            [jnp.asarray(t[-1])], topj, 1.5, 0.001, k,
            live=jnp.asarray(lpad), valid=jnp.asarray(vmask),
            interpret=True)
        check("fused_abnormal (degraded fleet)",
              np.array_equal(np.asarray(o), orr) and int(c) == cr
              and np.array_equal(np.asarray(ty), tyr))

    if failures:
        print(f"{failures} fused op(s) diverged from the oracle")
        return 1
    print("detect-fused smoke: all interpret-mode ops match the oracle")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
