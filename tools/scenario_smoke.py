"""Scenario smoke: the ground-truth bank's fast end-to-end gate.

``make scenario-smoke`` (part of ``make check``) replays the bank's two
fastest scenarios (``repro.scenarios.SMOKE_SCENARIOS``) from their
committed real-model traces at 512 and 2048 processes, scores the full
detect + backtrack + root-cause pipeline against each scenario's
machine-checkable ground truth, and asserts the declared accuracy
floors.  The per-cell rows are written to ``scenario-accuracy.csv`` (CI
uploads it as an artifact; the full bank x scale x backend table lives
in ``benchmarks/bench_casestudy.py``).

jax-free by construction with the default ``--backend numpy`` (committed
JSON traces only), so the jax-absent CI job runs it unchanged.  The CI
jax job additionally runs ``make scenario-smoke-jax`` (``--backend
jax``), scoring the SAME scenarios through the jitted detectors and
uploading the table as its own artifact — a jax-vs-numpy accuracy
divergence fails that job.  Exits non-zero on any floor violation,
failing ``make check`` loudly.
"""
from __future__ import annotations

import argparse
import sys
import time

SCALES = (512, 2048)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="scenario-accuracy.csv",
                    help="where to write the accuracy table")
    ap.add_argument("--scales", type=int, nargs="*", default=list(SCALES))
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"),
                    help="detection backend to score (jax requires jax)")
    args = ap.parse_args(argv)

    from repro.scenarios import SMOKE_SCENARIOS, get_scenario, run_and_score

    rows = ["scenario,n_procs,backend,channel,precision,recall,"
            "path_hit_rate,n_reported,n_truth,seconds,passes"]
    ok = True
    for name in SMOKE_SCENARIOS:
        sc = get_scenario(name)
        for n in args.scales:
            t0 = time.perf_counter()
            res, score = run_and_score(sc, n, backend=args.backend)
            dt = time.perf_counter() - t0
            passes = score.passes(sc.truth)
            ok &= passes
            rows.append(
                f"{name},{n},{args.backend},{res.channel},"
                f"{score.precision:.3f},"
                f"{score.recall:.3f},{score.path_hit_rate:.3f},"
                f"{score.n_reported},{score.n_truth},{dt:.3f},{passes}")
            verdict = "ok" if passes else "FLOOR VIOLATION"
            print(f"[{name} @ {n}] {score.row()}  {verdict}")
            if not passes:
                print(f"  floors: precision>={sc.truth.min_precision} "
                      f"recall>={sc.truth.min_recall} "
                      f"path_hit>={sc.truth.min_path_hit}", file=sys.stderr)

    with open(args.out, "w") as f:
        f.write("\n".join(rows) + "\n")
    if not ok:
        print("scenario smoke FAILED: accuracy under declared floors",
              file=sys.stderr)
        return 1
    print(f"\nscenario smoke OK (table -> {args.out})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
