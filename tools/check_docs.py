"""Executable-documentation checker (`make docs-check`).

Extracts every fenced code block from docs/*.md and README.md and checks
it:

* ```` ```python ```` blocks are EXECUTED, each file's blocks sharing one
  namespace (so a doc can build an example across several blocks, like a
  doctest session).  Anything raising fails the check with file:line.
* ```` ```python no-run ```` blocks are compiled only (syntax check) —
  for snippets that need heavyweight optional deps (jax model builds) or
  would be slow; keep these rare.
* other fences (bash, text, ...) are ignored.

Blocks run with src/ on sys.path and must not require jax: the analysis
layer documented here is the jax-free one, and this check is wired into
`make check` next to the jax-free --smoke canary.

    PYTHONPATH=src python tools/check_docs.py [files...]
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

FENCE = re.compile(r"^```(\S*)\s*(.*)$")


def blocks(path: pathlib.Path):
    """Yield (line_number, info_string, source) per fenced block."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1):
            info, tag = m.group(1), m.group(2).strip()
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield start, info, tag, "\n".join(body)
        i += 1


def check_file(path: pathlib.Path) -> int:
    failures = 0
    namespace: dict = {"__name__": f"docs_check::{path.name}"}
    for lineno, info, tag, src in blocks(path):
        if info != "python":
            continue
        label = f"{path.relative_to(REPO)}:{lineno}"
        try:
            code = compile(src, str(label), "exec")
            if tag != "no-run":
                exec(code, namespace)
        except Exception as e:                     # noqa: BLE001
            failures += 1
            print(f"FAIL {label}: {type(e).__name__}: {e}")
        else:
            mode = "compiled" if tag == "no-run" else "ran"
            print(f"ok   {label} ({mode})")
    return failures


def main(argv) -> int:
    targets = [pathlib.Path(a).resolve() for a in argv[1:]]
    if not targets:
        targets = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    failures = 0
    for t in targets:
        if t.exists():
            failures += check_file(t)
        else:
            failures += 1
            print(f"FAIL {t}: missing file")
    if failures:
        print(f"{failures} documentation block(s) failed")
        return 1
    print("all documentation blocks pass")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
