"""Run-store smoke: the multi-run regression service's end-to-end gate.

``make run-store-smoke`` (part of ``make check``) drives the two
acceptance claims of the run store (ISSUE 10), jax-free:

**A — cross-run diff accuracy.** The ``amdahl_serial_fraction``
scenario is replayed at 512 processes over its scale ladder twice —
once clean (``SerialFraction(frac=0.0)``, ideal scaling) and once
faulted — both runs recorded in a :class:`repro.runs.RunStore` and
compared with ``diff_runs``.  The injected vertex must be flagged with
precision >= 0.8 at k = |truth|, and a clean-vs-clean diff must flag
nothing.

**B — clustered diff at fleet scale.** A synthetic 65536-process train
step (the bench_graph_scale step PSG) with 64 slowed culprit processes
is recorded with ``cluster=64``: the store holds <= 64 behavior
representatives (>= 100x row compression), the diff still flags the
slowed vertex via the peak-row ratio, and the regressed cluster's
membership must contain exactly the true culprit processes.

Writes ``run-store-smoke.txt`` (uploaded as a CI artifact) and exits
non-zero on any violation, failing ``make check`` loudly.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time


def part_a(lines, n: int = 512) -> bool:
    from repro.runs import RunStore, diff_runs, render_regression_report
    from repro.scenarios import bank
    from repro.scenarios.faults import SerialFraction

    sc = bank.get_scenario("amdahl_serial_fraction")
    psg, plan, trace = sc.build(n)
    scales = [n // 8, n // 4, n // 2, n]
    t0 = time.perf_counter()
    bad = bank.simulate_series(psg, scales, plan.time_at_scale,
                               inject=plan.inject, seed=sc.seed)
    clean = SerialFraction(frac=0.0).plan(trace, psg, n, sc.seed)
    good = bank.simulate_series(psg, scales, clean.time_at_scale,
                                inject=clean.inject, seed=sc.seed)
    sim_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        store = RunStore(d)
        t0 = time.perf_counter()
        a = store.load(store.record(series=good, meta={"label": "clean"}))
        b = store.load(store.record(series=bad, meta={"label": "faulted"}))
        store_s = time.perf_counter() - t0
        diff = diff_runs(a, b)
        quiet = diff_runs(a, store.load(store.record(series=good)))
        report = render_regression_report(a, b, diff)

    truth = set(int(v) for v in plan.target_vids)
    k = max(1, len(truth))
    hits = sum(1 for v in diff.regressed_vids[:k] if v in truth)
    precision = hits / k
    ok = precision >= 0.8 and not quiet.regressions
    lines.append(f"[A] {sc.name} @ {n}: {len(diff.regressions)} regressed, "
                 f"precision@{k}={precision:.2f} "
                 f"(floor 0.80), clean-vs-clean regressions="
                 f"{len(quiet.regressions)} (want 0)  "
                 f"sim={sim_s:.2f}s store+load={store_s:.2f}s  "
                 f"{'ok' if ok else 'VIOLATION'}")
    for text in report.splitlines()[:14]:
        lines.append(f"    {text}")
    return ok


def part_b(lines, n: int = 65536, max_clusters: int = 64) -> bool:
    # the fleet PPG builder is shared with the graph-scale benchmark
    # (its run_store_fleet row) — one definition of "culprit procs"
    from benchmarks.bench_graph_scale import build_fleet_ppg, build_step_psg
    from repro.runs import RunStore, diff_runs, regressed_cluster

    psg = build_step_psg(n_comp=12, n_procs_hint=8)
    t0 = time.perf_counter()
    good, heavy, culprits = build_fleet_ppg(psg, n, slow=1.0)
    bad, _, _ = build_fleet_ppg(psg, n, slow=2.5)
    build_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        store = RunStore(d)
        t0 = time.perf_counter()
        a = store.load(store.record(ppg=good, cluster=max_clusters))
        b = store.load(store.record(ppg=bad, cluster=max_clusters))
        cluster_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        diff = diff_runs(a, b)
        diff_s = time.perf_counter() - t0

    reps = b.clustering.n_clusters
    compression = b.clustering.compression()
    k = regressed_cluster(b, diff)
    members = set(b.clustering.members(k).tolist()) if k is not None \
        else set()
    ok = (reps <= max_clusters
          and compression >= 100.0
          and heavy in diff.regressed_vids
          and k is not None
          and culprits <= members)
    lines.append(f"[B] fleet @ {n}: {reps} representatives "
                 f"(<= {max_clusters}), compression {compression:.0f}x "
                 f"(floor 100x), slowed vertex "
                 f"{'flagged' if heavy in diff.regressed_vids else 'MISSED'}"
                 f", regressed cluster {k} holds "
                 f"{len(culprits & members)}/{len(culprits)} culprits "
                 f"(members={len(members)})  "
                 f"build={build_s:.2f}s record+cluster={cluster_s:.2f}s "
                 f"diff={diff_s:.2f}s  {'ok' if ok else 'VIOLATION'}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="run-store-smoke.txt",
                    help="where to write the smoke report")
    ap.add_argument("--procs-a", type=int, default=512)
    ap.add_argument("--procs-b", type=int, default=65536)
    args = ap.parse_args(argv)

    lines = []
    ok = part_a(lines, args.procs_a)
    ok &= part_b(lines, args.procs_b)
    text = "\n".join(lines) + "\n"
    print(text, end="")
    with open(args.out, "w") as f:
        f.write(text)
    if not ok:
        print("run-store smoke FAILED", file=sys.stderr)
        return 1
    print(f"run-store smoke ok -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
