"""Chaos smoke: seeded fault-injection runs of the always-on monitor.

``make chaos-smoke`` (part of ``make check``) drives
:func:`repro.monitor.chaos.chaos_run` through a lossy, duplicating,
reordering transport — plus a dead host and an aggregator crash with
snapshot restore — and then :func:`repro.monitor.net.socket_chaos_run`
through REAL loopback TCP sockets behind the byte-level chaos proxy
(connection resets, torn frames, garbage bytes, stalls).  Each scenario
asserts the convergence contract: the monitor's final detection/
backtracking output, converged store, and rendered report match the
one-shot reference exactly, with fleet coverage stated.  The converged
report is written to ``chaos-report.txt`` (CI uploads it as an
artifact).

jax-free by construction (numpy backend); exits non-zero on any
divergence, so a broken ingestion/recovery path fails ``make check``
loudly.
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="chaos-report.txt",
                    help="where to write the converged report text")
    args = ap.parse_args(argv)

    from repro.monitor import chaos_run, socket_chaos_run

    scenarios = []

    # clean fleet under heavy faults: bit-identical convergence
    r = chaos_run(seed=args.seed, p_drop=0.25, p_dup=0.2, p_delay=0.35,
                  p_ack_loss=0.15)
    scenarios.append(("faulty-clean", r))

    # dead host + aggregator crash + snapshot restore
    with tempfile.TemporaryDirectory() as snapdir:
        r2 = chaos_run(seed=args.seed + 1, dead_hosts=(2,),
                       snapshot_dir=snapdir, crash_after_round=2)
    scenarios.append(("crash-degraded", r2))

    # real TCP through the byte-level chaos proxy: resets mid-stream,
    # frames torn mid-write, garbage bytes forcing resync, stalls —
    # the converged STORE and rendered REPORT must come out bit-
    # identical to the fault-free one-shot run
    r3 = socket_chaos_run(seed=args.seed + 2, p_reset=0.12, p_tear=0.1,
                          p_garbage=0.15, p_stall=0.05)
    scenarios.append(("socket-chaos", r3))

    lines = []
    ok = True
    for name, res in scenarios:
        stats = " ".join(f"{k}={v}" for k, v in
                         sorted(res.transport_stats.items()))
        verdict = "converged" if res.converged else "DIVERGED"
        ok &= res.converged
        lines.append(f"[{name}] {verdict}  abnormal={res.abnormal_match} "
                     f"paths={res.paths_match} store={res.store_match} "
                     f"report={res.report_match} "
                     f"dup_absorbed={res.duplicates_absorbed} "
                     f"applied={res.deltas_applied}  ({stats})")
    lines.append("")
    lines.append(scenarios[-1][1].report.text)
    text = "\n".join(lines)
    with open(args.out, "w") as f:
        f.write(text + "\n")
    print(text)
    if not ok:
        print("chaos smoke FAILED: monitor output diverged from one-shot",
              file=sys.stderr)
        return 1
    print(f"\nchaos smoke OK (report -> {args.out})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
